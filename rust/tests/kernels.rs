//! Kernel-dispatch acceptance suite: the per-kernel determinism contract
//! and the cross-kernel O(eps) parity, pinned end to end.
//!
//! What is *bitwise* (exact equality, per fixed kernel): thread count,
//! slice count, static-vs-assisting schedule, 1-column slices of a larger
//! product, and `gemm_par` vs `gemm`. What is *O(eps)* (tolerance
//! comparison, never equality): one kernel vs another — the SIMD variants
//! fuse multiply-add (one rounding per term) where the scalar reference
//! rounds twice, so their bits legitimately differ by rounding.
//!
//! On hosts without a SIMD kernel (`Kernel::all_available()` is just
//! `[Scalar]`) the cross-kernel tests degenerate to scalar-vs-scalar and
//! pass trivially; the fixed-kernel invariance sweep still exercises the
//! full dispatch plumbing (config → session → pool batch capture).

use paraht::api::{reduce_seq, HtSession};
use paraht::config::Config;
use paraht::linalg::gemm::{gemm, gemm_par, Trans};
use paraht::linalg::kernels::{self, Kernel, KernelChoice};
use paraht::linalg::matrix::Matrix;
use paraht::pencil::random::random_pencil;
use paraht::util::proptest::max_abs_diff;
use paraht::util::rng::Rng;

/// Bitwise matrix comparison: `-0.0 != 0.0`, NaN payloads distinguish —
/// stricter than `max_abs_diff == 0`, which the determinism tests need
/// because the adversarial tiles below deliberately produce signed zeros.
fn assert_bitwise(a: &Matrix, b: &Matrix, label: &str) {
    assert_eq!(a.rows(), b.rows(), "{label}: row mismatch");
    assert_eq!(a.cols(), b.cols(), "{label}: col mismatch");
    for (i, (x, y)) in a.data().iter().zip(b.data()).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{label}: element {i} differs ({x:e} vs {y:e})"
        );
    }
}

/// A tile salted with adversarial values: denormals, signed zeros, and
/// large/small magnitude mixes that stress the fused-vs-unfused rounding
/// delta and the zero-padding of partial micro-panels.
fn adversarial(rows: usize, cols: usize, rng: &mut Rng) -> Matrix {
    let mut m = Matrix::randn(rows, cols, rng);
    for j in 0..cols {
        for i in 0..rows {
            match (i * 31 + j * 7) % 11 {
                0 => m[(i, j)] = 0.0,
                1 => m[(i, j)] = -0.0,
                2 => m[(i, j)] = 1e-310,        // subnormal
                3 => m[(i, j)] = -3e-312,       // negative subnormal
                4 => m[(i, j)] *= 1e150,
                5 => m[(i, j)] *= 1e-150,
                _ => {}
            }
        }
    }
    m
}

#[test]
fn kernel_choice_parses_and_detect_clamps_to_runnable() {
    // Parse-level: every spelling round-trips, garbage is rejected.
    for choice in [
        KernelChoice::Auto,
        KernelChoice::Scalar,
        KernelChoice::Avx2,
        KernelChoice::Neon,
    ] {
        assert_eq!(KernelChoice::parse(choice.name()), Some(choice));
        assert_eq!(
            KernelChoice::parse(&format!("  {}  ", choice.name().to_uppercase())),
            Some(choice)
        );
    }
    assert_eq!(KernelChoice::parse("avx512"), None);
    assert_eq!(KernelChoice::parse(""), None);

    // Resolve-level: every request — including ones this architecture
    // cannot honor — clamps to a kernel the CPU can actually run.
    let available = Kernel::all_available();
    assert_eq!(available[0], Kernel::Scalar, "scalar is always available and first");
    for choice in [
        KernelChoice::Auto,
        KernelChoice::Scalar,
        KernelChoice::Avx2,
        KernelChoice::Neon,
    ] {
        let k = Kernel::detect(choice);
        assert!(available.contains(&k), "{choice:?} resolved to unavailable {k:?}");
    }
    assert_eq!(Kernel::detect(KernelChoice::Scalar), Kernel::Scalar);
    assert!(!Kernel::Scalar.fused(), "scalar is the unfused reference");
}

#[test]
fn fixed_kernel_reduction_is_invariant_across_threads_and_schedules() {
    // The narrowed determinism contract, per kernel: with `Config::kernel`
    // pinned, thread count / slice count / schedule choice must not move a
    // single bit relative to the sequential oracle under the SAME kernel.
    // n = 36 with r·p = 12 keeps every path (panel clip, sweep groups)
    // alive while the sweep stays fast.
    let mut rng = Rng::new(0x4B_01);
    let pencil = random_pencil(36, &mut rng);
    for kernel in Kernel::all_available() {
        let cfg = Config {
            r: 4,
            p: 3,
            q: 3,
            slices: 6,
            kernel: kernel.choice(),
            ..Config::default()
        };
        let oracle = reduce_seq(&pencil.a, &pencil.b, &cfg).unwrap();
        oracle.verify(&pencil.a, &pencil.b).assert_ok(1e-10);
        for threads in [1usize, 2, 4] {
            for dynamic in [false, true] {
                let run_cfg =
                    Config { threads, dynamic_schedule: dynamic, ..cfg.clone() };
                let mut session =
                    HtSession::builder().config(run_cfg).build().unwrap();
                let run = session.reduce(&pencil.a, &pencil.b).unwrap();
                let label = format!(
                    "kernel={} threads={threads} dynamic={dynamic}",
                    kernel.name()
                );
                assert_bitwise(&oracle.h, &run.h, &format!("{label}: H"));
                assert_bitwise(&oracle.t, &run.t, &format!("{label}: T"));
                assert_bitwise(&oracle.q, &run.q, &format!("{label}: Q"));
                assert_bitwise(&oracle.z, &run.z, &format!("{label}: Z"));
            }
        }
    }
}

#[test]
fn simd_matches_scalar_to_rounding_on_random_tiles() {
    // Cross-kernel contract: same product, different rounding. The fused
    // kernels must agree with the scalar reference to O(eps)-per-term —
    // far tighter than any algorithmic difference could produce, far
    // looser than bitwise. Sizes straddle KC = 256 so the k-blocking
    // boundary (where per-block alpha application and accumulator
    // carry-over live) is crossed.
    let mut rng = Rng::new(0x4B_02);
    for &(m, n, k) in &[(64usize, 48usize, 300usize), (100, 100, 100), (8, 4, 513)] {
        let a = Matrix::randn(m, k, &mut rng);
        let b = Matrix::randn(k, n, &mut rng);
        let reference = kernels::with_kernel(Kernel::Scalar, || {
            let mut c = Matrix::zeros(m, n);
            gemm(1.0, a.as_ref(), Trans::No, b.as_ref(), Trans::No, 0.0, c.as_mut());
            c
        });
        let scale = a.norm_fro() * b.norm_fro();
        for kernel in Kernel::all_available() {
            if kernel == Kernel::Scalar {
                continue;
            }
            let c = kernels::with_kernel(kernel, || {
                let mut c = Matrix::zeros(m, n);
                gemm(1.0, a.as_ref(), Trans::No, b.as_ref(), Trans::No, 0.0, c.as_mut());
                c
            });
            let diff = max_abs_diff(&reference, &c);
            assert!(
                diff <= 1e-13 * scale,
                "{} vs scalar on {m}x{n}x{k}: diff {diff:e} > 1e-13 * {scale:e}",
                kernel.name()
            );
        }
    }
}

#[test]
fn simd_matches_scalar_on_adversarial_tiles() {
    // Subnormals, signed zeros and huge dynamic range: the per-element
    // bound is computed from |A|·|B| (the worst-case accumulated
    // magnitude), with an absolute floor so all-subnormal dot products —
    // where the relative bound underflows to zero — still pass only if
    // the kernels agree to within absolute noise.
    let mut rng = Rng::new(0x4B_03);
    let (m, n, k) = (40usize, 24usize, 280usize);
    let a = adversarial(m, k, &mut rng);
    let b = adversarial(k, n, &mut rng);
    let abs_a = Matrix::from_fn(m, k, |i, j| a[(i, j)].abs());
    let abs_b = Matrix::from_fn(k, n, |i, j| b[(i, j)].abs());
    let run = |kernel: Kernel| {
        kernels::with_kernel(kernel, || {
            let mut c = Matrix::zeros(m, n);
            gemm(1.0, a.as_ref(), Trans::No, b.as_ref(), Trans::No, 0.0, c.as_mut());
            c
        })
    };
    let reference = run(Kernel::Scalar);
    let mut absprod = Matrix::zeros(m, n);
    kernels::with_kernel(Kernel::Scalar, || {
        gemm(
            1.0,
            abs_a.as_ref(),
            Trans::No,
            abs_b.as_ref(),
            Trans::No,
            0.0,
            absprod.as_mut(),
        );
    });
    for kernel in Kernel::all_available() {
        if kernel == Kernel::Scalar {
            continue;
        }
        let c = run(kernel);
        for j in 0..n {
            for i in 0..m {
                let diff = (reference[(i, j)] - c[(i, j)]).abs();
                let bound = 1e-13 * absprod[(i, j)] + 1e-300;
                assert!(
                    diff <= bound,
                    "{} vs scalar at ({i},{j}): diff {diff:e} > {bound:e}",
                    kernel.name()
                );
            }
        }
    }
}

#[test]
fn one_column_slices_match_the_full_product_bitwise_per_kernel() {
    // Slicing invariance at its sharpest edge: a 1-column slice of C takes
    // the `gemv_n1` fast path, which branches on `Kernel::fused()` exactly
    // so this test can hold — per kernel, column-by-column assembly must
    // reproduce the packed full product bit for bit, signed zeros
    // included (the adversarial tile plants them).
    let mut rng = Rng::new(0x4B_04);
    let (m, n, k) = (60usize, 12usize, 270usize);
    for (tile, tag) in [
        (
            (Matrix::randn(m, k, &mut rng), Matrix::randn(k, n, &mut rng)),
            "random",
        ),
        ((adversarial(m, k, &mut rng), adversarial(k, n, &mut rng)), "adversarial"),
    ] {
        let (a, b) = tile;
        for kernel in Kernel::all_available() {
            kernels::with_kernel(kernel, || {
                let mut full = Matrix::zeros(m, n);
                gemm(1.0, a.as_ref(), Trans::No, b.as_ref(), Trans::No, 0.0, full.as_mut());
                let mut sliced = Matrix::zeros(m, n);
                for j in 0..n {
                    gemm(
                        1.0,
                        a.as_ref(),
                        Trans::No,
                        b.sub(0..k, j..j + 1),
                        Trans::No,
                        0.0,
                        sliced.sub_mut(0..m, j..j + 1),
                    );
                }
                assert_bitwise(
                    &full,
                    &sliced,
                    &format!("{tag} tile, kernel={}", kernel.name()),
                );
            });
        }
    }
}

#[test]
fn gemm_par_is_bitwise_gemm_per_kernel() {
    // The pool inherits the submitter's kernel (batch capture), so the
    // parallel panels run the same microkernel as the sequential call —
    // and the panel split itself is bitwise-invariant. Both facts at once:
    // per kernel, `gemm_par` at 4 threads equals `gemm` exactly.
    let mut rng = Rng::new(0x4B_05);
    let (m, n, k) = (96usize, 80usize, 260usize);
    let a = Matrix::randn(m, k, &mut rng);
    let b = Matrix::randn(k, n, &mut rng);
    for kernel in Kernel::all_available() {
        kernels::with_kernel(kernel, || {
            let mut seq = Matrix::zeros(m, n);
            gemm(1.0, a.as_ref(), Trans::No, b.as_ref(), Trans::No, 0.0, seq.as_mut());
            let mut par = Matrix::zeros(m, n);
            gemm_par(1.0, a.as_ref(), Trans::No, b.as_ref(), Trans::No, 0.0, par.as_mut(), 4);
            assert_bitwise(&seq, &par, &format!("gemm_par kernel={}", kernel.name()));
        });
    }
}

#[test]
fn builder_kernel_and_env_knob_agree_on_resolution() {
    // The two override routes — `HtSession::builder().kernel(...)` and a
    // `Config` literal — must resolve identically, and `Auto` must resolve
    // to the process default the env knob establishes.
    let via_builder = HtSession::builder()
        .kernel(KernelChoice::Scalar)
        .build()
        .unwrap()
        .config()
        .resolved_kernel();
    let via_config =
        Config { kernel: KernelChoice::Scalar, ..Config::default() }.resolved_kernel();
    assert_eq!(via_builder, via_config);
    assert_eq!(via_builder, Kernel::Scalar);
    assert_eq!(
        Config::default().resolved_kernel(),
        kernels::process_default(),
        "Auto resolves to the process default"
    );
}
