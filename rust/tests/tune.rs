//! Integration tests for the autotuner (`paraht::tune`): search output
//! validity, profile persistence, and the serving tier's behaviour with
//! tuned profiles installed, reloaded, and corrupted.
//!
//! The load-bearing contract everywhere below: **tuned profiles change
//! geometry, never results**. Every profiled reduction must be bitwise
//! `api::reduce_seq` under its *effective* config — the profile overlay
//! for that size, then the serving band clip — and a profile that fails
//! to load must degrade the tier to untuned defaults, never take it down.

use paraht::api::reduce_seq;
use paraht::config::Config;
use paraht::error::Error;
use paraht::pencil::random::random_pencil;
use paraht::pencil::Pencil;
use paraht::serve::{ServeConfig, ShardRouter, SubmitQueue};
use paraht::tune::{Autotuner, ClassProfile, TuneOptions, TunedProfile};
use paraht::util::proptest::max_abs_diff;
use paraht::util::rng::Rng;

/// A unique scratch path in the OS temp dir (tests run concurrently in
/// one process; the tag keeps them from clobbering each other).
fn temp_path(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("paraht_tune_test_{}_{tag}.json", std::process::id()))
}

/// Assert two decompositions are bitwise identical (0.0 max-abs-diff on
/// all four factors — no tolerance, the determinism contract is exact).
fn assert_bitwise(d: &paraht::HtDecomposition, o: &paraht::HtDecomposition, what: &str) {
    assert_eq!(max_abs_diff(&d.h, &o.h), 0.0, "{what}: H diverges");
    assert_eq!(max_abs_diff(&d.t, &o.t), 0.0, "{what}: T diverges");
    assert_eq!(max_abs_diff(&d.q, &o.q), 0.0, "{what}: Q diverges");
    assert_eq!(max_abs_diff(&d.z, &o.z), 0.0, "{what}: Z diverges");
}

/// Hand-built two-class profile with *distinct* geometry per class, so a
/// cache-key or workspace bug cannot hide behind identical configs.
fn two_class_profile() -> TunedProfile {
    TunedProfile {
        classes: vec![
            ClassProfile {
                n_min: 9,
                n_max: 20,
                r: 4,
                p: 2,
                q: 2,
                slices: 0,
                threads: 0,
                predicted_makespan: 0.0,
                default_makespan: 0.0,
                trace_n: 16,
            },
            // Deliberately NOT the tests' base geometry (r=8,p=4,q=4):
            // the reload test below relies on the retuned effective
            // config being a *different* cache key than the base's.
            ClassProfile {
                n_min: 21,
                n_max: 0,
                r: 6,
                p: 2,
                q: 4,
                slices: 0,
                threads: 0,
                predicted_makespan: 0.0,
                default_makespan: 0.0,
                trace_n: 32,
            },
        ],
    }
}

// ---------------------------------------------------------------------
// Satellite: search-output properties.
// ---------------------------------------------------------------------

/// Property: every config the tuner emits passes `Config::validate_for`
/// across its whole size class (the floor, the trace size, and sampled
/// interior/deep sizes), and the chosen config's simulator-predicted
/// makespan never exceeds the default config's prediction on the same
/// trace — the argmin construction must make both hold for any seed.
#[test]
fn tuner_emits_valid_configs_that_never_predict_slower() {
    for seed in [1u64, 0xBEE5, 0x7A_57E5] {
        let opts = TuneOptions { sizes: vec![12, 24], threads: 2, budget: 3, seed };
        let tuner = Autotuner::new(Config::default(), opts).unwrap();
        let (profile, reports) = tuner.run().unwrap();
        profile.validate().expect("emitted profile validates");
        assert_eq!(profile.classes.len(), 2);
        assert_eq!(profile.classes.len(), reports.len());
        let base = Config::default();
        for (c, rep) in profile.classes.iter().zip(&reports) {
            assert_eq!(*c, rep.chosen, "report and profile agree on the winner");
            assert!(
                c.predicted_makespan <= rep.default_predicted,
                "class n>={}: chosen {} predicts slower than default {}",
                c.n_min,
                c.predicted_makespan,
                rep.default_predicted
            );
            assert!(rep.candidates >= 1 && rep.candidates <= 3, "budget respected");
            // The overlaid config must be valid at every size the class
            // covers; sample the floor, the trace size, and deep sizes.
            let hi = if c.n_max == 0 { c.n_min + 91 } else { c.n_max };
            for n in [c.n_min, c.trace_n, (c.n_min + hi) / 2, hi] {
                assert!(c.covers(n), "sampled n={n} inside class");
                let eff = profile.apply(&base, n);
                eff.validate_for(n).unwrap_or_else(|e| {
                    panic!("class n>={}: emitted config invalid at n={n}: {e}", c.n_min)
                });
            }
        }
        // Classes tile the size axis without overlap: the first class
        // hands off to the second exactly where the midpoint boundary
        // fell, and the last class is open-ended.
        assert_eq!(profile.classes[0].n_max + 1, profile.classes[1].n_min);
        assert_eq!(profile.classes[1].n_max, 0);
    }
}

// ---------------------------------------------------------------------
// Satellite: persistence round-trip + corrupt-artifact fallback.
// ---------------------------------------------------------------------

/// Save → load through a real file is the identity, bit-exact floats
/// included.
#[test]
fn profile_save_load_round_trip_is_identity() {
    let mut p = two_class_profile();
    // Awkward floats: shortest round-trip Display must preserve bits.
    p.classes[0].predicted_makespan = 1.0 / 3.0;
    p.classes[0].default_makespan = 0.1 + 0.2;
    p.classes[1].predicted_makespan = f64::MIN_POSITIVE;
    let path = temp_path("round_trip");
    p.save(&path).unwrap();
    let back = TunedProfile::load(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    assert_eq!(back, p, "load(save(p)) must be p");
    assert_eq!(
        back.classes[0].predicted_makespan.to_bits(),
        (1.0f64 / 3.0).to_bits(),
        "floats survive the file round trip exactly"
    );
}

/// Truncated, corrupt, and wrong-version artifacts fail with *typed*
/// errors (protocol for malformed JSON, config for semantic problems),
/// `load_or_warn` turns any of them into a clean `None`, and a router
/// built without a profile — the fallback path — still serves bitwise.
#[test]
fn corrupt_profiles_fail_typed_and_the_tier_falls_back_clean() {
    let good = two_class_profile().to_json();
    let cases: [(&str, String, fn(&Error) -> bool); 4] = [
        ("truncated", good[..good.len() / 2].to_string(), |e| matches!(e, Error::Protocol(_))),
        ("garbage", "}{ not json at all".to_string(), |e| matches!(e, Error::Protocol(_))),
        (
            "wrong_version",
            good.replace("\"schema_version\": 1", "\"schema_version\": 99"),
            |e| matches!(e, Error::Config(_)),
        ),
        (
            "bad_geometry",
            good.replace("\"r\": 4", "\"r\": 1"),
            |e| matches!(e, Error::Config(_)),
        ),
    ];
    for (tag, text, is_expected) in &cases {
        let path = temp_path(*tag);
        std::fs::write(&path, text).unwrap();
        let err = TunedProfile::load(&path).unwrap_err();
        assert!(is_expected(&err), "{tag}: unexpected error type: {err}");
        // The startup path: warn once, fall back to defaults, no panic.
        assert!(
            TunedProfile::load_or_warn(path.to_str().unwrap()).is_none(),
            "{tag}: load_or_warn must swallow the failure"
        );
        let _ = std::fs::remove_file(&path);
    }
    // Missing file is an Io error (and a clean None through load_or_warn).
    let gone = temp_path("never_written");
    assert!(matches!(TunedProfile::load(&gone).unwrap_err(), Error::Io(_)));
    assert!(TunedProfile::load_or_warn(gone.to_str().unwrap()).is_none());

    // Fallback serving: a tier with no profile is the untuned tier.
    let cfg = ServeConfig {
        shards: 2,
        base: Config { r: 8, p: 4, q: 4, ..Config::default() },
        profile: None,
        ..ServeConfig::default()
    };
    let base = cfg.base.clone();
    let router = ShardRouter::new(cfg).unwrap();
    let mut rng = Rng::new(0xFA11_BACC);
    for n in [2usize, 6, 24] {
        let p = random_pencil(n, &mut rng);
        let d = router.reduce(&p.a, &p.b).unwrap();
        let oracle = reduce_seq(&p.a, &p.b, &base.clipped_for(n)).unwrap();
        assert_bitwise(&d, &oracle, "untuned fallback");
    }
}

// ---------------------------------------------------------------------
// Satellite: profiled serving — mixed floods, cache soundness, reloads.
// ---------------------------------------------------------------------

/// A profiled router fed a mixed-size flood through the submission queue
/// answers every job bitwise-identical to `reduce_seq` under that size's
/// effective config — including `n = 2` (the no-op), sizes below the
/// band (clip path), and sizes below every class floor (base fallback).
#[test]
fn profiled_flood_is_bitwise_the_oracle_at_every_size() {
    let profile = two_class_profile();
    let cfg = ServeConfig {
        shards: 2,
        cache_entries: 16,
        base: Config { r: 8, p: 4, q: 4, ..Config::default() },
        profile: Some(profile.clone()),
        ..ServeConfig::default()
    };
    let base = cfg.base.clone();
    let queue = SubmitQueue::new(ShardRouter::new(cfg).unwrap());
    let mut rng = Rng::new(0xF100D);
    // n = 2 and n = 6 sit below every class floor (base config, and 6 is
    // also below the base band → clip); 10/16 hit class 0, 24/33 class 1.
    let sizes = [2usize, 6, 10, 16, 24, 33];
    let pool: Vec<Pencil> = sizes.iter().map(|&n| random_pencil(n, &mut rng)).collect();
    let handle = queue.handle();
    let tickets: Vec<_> = (0..2 * pool.len())
        .map(|i| {
            let p = &pool[i % pool.len()];
            (i % pool.len(), handle.submit(p.a.clone(), p.b.clone()).unwrap())
        })
        .collect();
    for (idx, ticket) in tickets {
        let p = &pool[idx];
        let n = p.n();
        let d = ticket.wait().unwrap();
        let eff = profile.apply(&base, n).clipped_for(n);
        let oracle = reduce_seq(&p.a, &p.b, &eff).unwrap();
        assert_bitwise(&d, &oracle, &format!("profiled flood n={n}"));
    }
    // The second pass of the flood was bitwise-identical submissions:
    // with 16 cache entries for 6 distinct pencils every repeat is a hit,
    // so exactly one reduction ran per distinct pencil — the cache key
    // (which carries the tuned effective config) neither aliased two
    // size classes together nor split one pencil into misses.
    assert_eq!(queue.router().stats().reduced_total(), pool.len() as u64);
    queue.shutdown();
}

/// Cache keys stay sound when tuned geometry differs across size classes
/// and changes under a live reload: re-reducing a pencil after a reload
/// that changed its effective config re-executes (new key) and matches
/// the *new* oracle; reloading back restores hits against the original
/// entry. A stale or mislabeled entry would fail the bitwise gate.
#[test]
fn cache_stays_sound_across_reloads_that_retune_a_size() {
    let profile = two_class_profile();
    let cfg = ServeConfig {
        shards: 1,
        cache_entries: 8,
        base: Config { r: 8, p: 4, q: 4, ..Config::default() },
        profile: None, // start untuned
        ..ServeConfig::default()
    };
    let base = cfg.base.clone();
    let router = ShardRouter::new(cfg).unwrap();
    let mut rng = Rng::new(0x0C0DE);
    let p = random_pencil(24, &mut rng);

    // Untuned: base geometry (r=8,p=4,q=4).
    let d0 = router.reduce(&p.a, &p.b).unwrap();
    let o0 = reduce_seq(&p.a, &p.b, &base.clipped_for(24)).unwrap();
    assert_bitwise(&d0, &o0, "untuned first pass");
    assert_eq!(router.stats().reduced_total(), 1);

    // Reload: n=24 now retunes to class 1's geometry — same pencil, new
    // effective config, so the cached untuned entry must NOT be served.
    router.reload_profile(Some(profile.clone())).unwrap();
    let d1 = router.reduce(&p.a, &p.b).unwrap();
    let o1 = reduce_seq(&p.a, &p.b, &profile.apply(&base, 24).clipped_for(24)).unwrap();
    assert_bitwise(&d1, &o1, "tuned second pass");
    assert_eq!(router.stats().reduced_total(), 2, "retuned config is a distinct cache key");

    // Reload back to untuned: the original entry is still valid for the
    // base effective config and must be served without re-executing.
    router.reload_profile(None).unwrap();
    let d2 = router.reduce(&p.a, &p.b).unwrap();
    assert_bitwise(&d2, &o0, "untuned third pass");
    assert_eq!(router.stats().reduced_total(), 2, "restored config hits the original entry");

    // An invalid reload is rejected with a typed error and changes
    // nothing: the tier keeps serving under the last good profile.
    let mut bad = profile.clone();
    bad.classes[0].r = bad.classes[0].n_min; // r >= n_min
    assert!(matches!(router.reload_profile(Some(bad)).unwrap_err(), Error::Config(_)));
    let d3 = router.reduce(&p.a, &p.b).unwrap();
    assert_bitwise(&d3, &o0, "after rejected reload");
}

/// End-to-end: run the tuner, persist the profile, load it from disk the
/// way a serving process would, and verify the tier serves bitwise under
/// the tuned configs — the full record → search → save → load → serve
/// loop the `tune` CLI subcommand wires together.
#[test]
fn tuner_profile_survives_disk_and_serves_bitwise() {
    let base = Config { r: 8, p: 4, q: 4, ..Config::default() };
    let opts = TuneOptions { sizes: vec![16, 28], threads: 2, budget: 2, seed: 0xD15C };
    let (profile, _reports) = Autotuner::new(base.clone(), opts).unwrap().run().unwrap();
    let path = temp_path("end_to_end");
    profile.save(&path).unwrap();
    let loaded = TunedProfile::load_or_warn(path.to_str().unwrap())
        .expect("freshly saved profile loads");
    let _ = std::fs::remove_file(&path);
    assert_eq!(loaded, profile);

    let cfg = ServeConfig {
        shards: 2,
        base: base.clone(),
        profile: Some(loaded.clone()),
        ..ServeConfig::default()
    };
    let router = ShardRouter::new(cfg).unwrap();
    let mut rng = Rng::new(0xE2E);
    for n in [2usize, 7, 16, 28, 40] {
        let p = random_pencil(n, &mut rng);
        let d = router.reduce(&p.a, &p.b).unwrap();
        let eff = loaded.apply(&base, n).clipped_for(n);
        let oracle = reduce_seq(&p.a, &p.b, &eff).unwrap();
        assert_bitwise(&d, &oracle, &format!("tuned-from-disk n={n}"));
    }
}
