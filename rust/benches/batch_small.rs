//! Bench: batch throughput of the session front door —
//! `HtSession::reduce_batch` on batches of small pencils, the regime where
//! per-pencil setup (and per-pencil task graphs) would drown the actual
//! reduction work. One pencil runs as one indivisible sequential job on
//! one worker; the measurement is pencils/second by batch size and thread
//! count.
//!
//! Writes `BENCH_batch.json` (override: `PALLAS_BENCH_OUT`) so the CI perf
//! job accumulates a throughput trajectory per commit — always *before*
//! the shape assertion runs, so a hard-mode failure never discards the
//! data.
//!
//! Env knobs (canonical `PALLAS_` names; legacy `PARAHT_` aliases accepted
//! — see `util::env`):
//! * `PALLAS_BATCH_N=24` — pencil size.
//! * `PALLAS_BATCH_SIZES=64,128,256` — batch sizes to sweep.
//! * `PALLAS_BENCH_SOFT` / `PALLAS_BENCH_TOL` — soften / relax the
//!   threaded-no-slower assertion (see `experiments::common`).

use paraht::api::{reduce_seq, HtSession};
use paraht::config::Config;
use paraht::experiments::common;
use paraht::pencil::random::{random_pencil, Pencil};
use paraht::util::env;
use paraht::util::proptest::max_abs_diff;
use paraht::util::rng::Rng;
use std::fmt::Write as _;
use std::time::Instant;

/// Thread counts recorded for the sweep (subset of the paper's Fig. 9a
/// axis that fits CI runners).
const THREADS: &[usize] = &[1, 4, 7];

struct Row {
    batch: usize,
    threads: usize,
    secs: f64,
    pencils_per_sec: f64,
}

/// Best-of-2 wall-clock of one full batch reduction (plus one warmup).
fn time_batch(session: &mut HtSession, pencils: &[Pencil]) -> f64 {
    let mut best = f64::INFINITY;
    for rep in 0..3 {
        let t = Instant::now();
        let out = session.reduce_batch(pencils).expect("batch reduces");
        let secs = t.elapsed().as_secs_f64();
        assert_eq!(out.len(), pencils.len());
        if rep > 0 {
            best = best.min(secs);
        }
    }
    best
}

fn main() {
    // Floor of 8 keeps the fixed r=4 band valid (r < n) no matter what
    // PALLAS_BATCH_N is set to.
    let n = env::batch_n(24).max(8);
    let batches = env::batch_sizes(&[64, 128, 256]);
    // Small-pencil tuning: the band must fit the pencils (r < n).
    let cfg = Config { r: 4, p: 2, q: 4, ..Config::default() };
    eprintln!(
        "batch_small: n={n}, batches {batches:?} (set PALLAS_BATCH_N / PALLAS_BATCH_SIZES to change)"
    );

    let mut rng = Rng::new(2424);
    let largest = batches.iter().copied().max().unwrap_or(0);
    let pool: Vec<Pencil> = (0..largest).map(|_| random_pencil(n, &mut rng)).collect();

    // Structural parity spot check: the batch path must be bitwise the
    // sequential oracle on every pencil (hard assert — not timing).
    {
        let mut s = HtSession::builder().config(cfg.clone()).threads(4).build().unwrap();
        let out = s.reduce_batch(&pool[..4.min(pool.len())]).unwrap();
        for (p, d) in pool.iter().zip(&out) {
            let oracle = reduce_seq(&p.a, &p.b, &cfg).unwrap();
            assert_eq!(max_abs_diff(&d.h, &oracle.h), 0.0, "batch H diverges from oracle");
            assert_eq!(max_abs_diff(&d.t, &oracle.t), 0.0, "batch T diverges from oracle");
            assert_eq!(max_abs_diff(&d.q, &oracle.q), 0.0, "batch Q diverges from oracle");
            assert_eq!(max_abs_diff(&d.z, &oracle.z), 0.0, "batch Z diverges from oracle");
        }
    }

    println!("{:<8}{:>9}{:>12}{:>16}", "batch", "threads", "secs", "pencils/sec");
    let mut rows: Vec<Row> = Vec::new();
    for &bs in &batches {
        let pencils = &pool[..bs.min(pool.len())];
        for &t in THREADS {
            let mut session =
                HtSession::builder().config(cfg.clone()).threads(t).build().unwrap();
            let secs = time_batch(&mut session, pencils);
            let pps = pencils.len() as f64 / secs;
            println!("{bs:<8}{t:>9}{secs:>12.4}{pps:>16.1}");
            rows.push(Row { batch: bs, threads: t, secs, pencils_per_sec: pps });
        }
    }

    // Shape condition: threaded batching must not be slower than the
    // 1-thread loop on the largest batch. Timing-sensitive — soft mode /
    // PALLAS_BENCH_TOL relax it on noisy hardware. Evaluated here, but
    // asserted only after the JSON artifact is written.
    let pps_at = |bs: usize, t: usize| {
        rows.iter()
            .find(|r| r.batch == bs && r.threads == t)
            .map(|r| r.pencils_per_sec)
            .unwrap_or(f64::NAN)
    };
    let (t1, t4) = (pps_at(largest, 1), pps_at(largest, 4));
    let speedup_4t = t4 / t1;
    let cond_par = largest == 0 || speedup_4t >= 1.0 / common::bench_tol();

    // ---- Emit BENCH_batch.json. ----
    let mut body = String::new();
    let _ = writeln!(body, "  \"n\": {n},");
    body.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let _ = write!(
            body,
            "    {{\"batch\": {}, \"threads\": {}, \"secs\": {:.6}, \"pencils_per_sec\": {}}}",
            r.batch,
            r.threads,
            r.secs,
            common::json_num(r.pencils_per_sec)
        );
        body.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    body.push_str("  ],\n");
    let _ = writeln!(body, "  \"speedup_4t\": {},", common::json_num(speedup_4t));
    let _ = write!(body, "  \"checks_held\": {cond_par}");
    common::write_bench_json("BENCH_batch.json", "batch_small", &body);

    if common::bench_check(
        cond_par,
        &format!(
            "4-thread batch throughput must not trail 1-thread: {t4:.1} vs {t1:.1} pencils/sec"
        ),
    ) {
        println!("\nshape checks OK (batch parity exact; threaded batching no slower)");
    }
}
