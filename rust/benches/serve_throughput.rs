//! Bench: serving-tier throughput — mixed-size pencil floods through
//! `serve::SubmitQueue` (shard router + async queue + result cache).
//!
//! Two sweeps:
//! * **Geometry** — pencils/sec for several `shards × threads_per_shard`
//!   configurations on an all-distinct flood (cache disabled, so the
//!   numbers isolate shard scaling).
//! * **Cache hit-rate** — a fixed geometry flooded with controlled
//!   duplication (`unique` distinct pencils cycled through `jobs`
//!   submissions); hit/miss counters are *structural* (hard-asserted:
//!   misses = distinct pencils, hits = the rest — duplicates of a pencil
//!   land on one shard's FIFO, so no racing double-miss exists), while
//!   throughput ratios stay timing-sensitive (soft mode applies).
//!
//! Writes `BENCH_serve.json` (override: `PALLAS_BENCH_OUT`) before any
//! timing-sensitive assertion, so a hard-mode failure never discards the
//! data. Bitwise parity of served results against the sequential oracle
//! is hard-asserted up front.
//!
//! Env knobs (canonical `PALLAS_` names; legacy `PARAHT_` aliases — see
//! `util::env`):
//! * `PALLAS_SERVE_JOBS=160` — flood length per sweep point.
//! * `PALLAS_SERVE_SIZES=16,24,32` — pencil-size mix.
//! * `PALLAS_BENCH_SOFT` / `PALLAS_BENCH_TOL` — soften / relax the
//!   shard-scaling assertion.

use paraht::api::reduce_seq;
use paraht::config::Config;
use paraht::experiments::common;
use paraht::pencil::random::random_pencil;
use paraht::pencil::Pencil;
use paraht::serve::{ServeConfig, ShardRouter, SubmitQueue};
use paraht::util::env;
use paraht::util::proptest::max_abs_diff;
use paraht::util::rng::Rng;
use std::fmt::Write as _;
use std::time::Instant;

/// `(shards, threads_per_shard)` sweep points.
const GEOMETRIES: &[(usize, usize)] = &[(1, 1), (2, 1), (4, 1), (2, 2)];

/// Small-pencil serving tuning (band must fit the smallest size).
fn base_cfg() -> Config {
    Config { r: 4, p: 2, q: 4, ..Config::default() }
}

fn serve_cfg(shards: usize, threads: usize, cache_entries: usize) -> ServeConfig {
    ServeConfig {
        shards,
        threads_per_shard: threads,
        cache_entries,
        base: base_cfg(),
        ..ServeConfig::default()
    }
}

/// Flood `jobs` submissions cycling through `pool`, wait for every
/// ticket, return wall seconds (panics on any failed job — the bench only
/// times healthy floods).
fn flood(queue: &SubmitQueue, pool: &[Pencil], jobs: usize) -> f64 {
    let handle = queue.handle();
    let t = Instant::now();
    let tickets: Vec<_> = (0..jobs)
        .map(|i| {
            let p = &pool[i % pool.len()];
            handle.submit(p.a.clone(), p.b.clone()).expect("flood submission accepted")
        })
        .collect();
    for ticket in tickets {
        ticket.wait().expect("served reduction succeeds");
    }
    t.elapsed().as_secs_f64()
}

struct GeomRow {
    shards: usize,
    threads: usize,
    jobs: usize,
    secs: f64,
    pencils_per_sec: f64,
}

struct CacheRow {
    unique: usize,
    jobs: usize,
    hits: u64,
    misses: u64,
    hit_rate: f64,
    secs: f64,
    pencils_per_sec: f64,
}

fn main() {
    let sizes = env::serve_sizes(&[16, 24, 32]);
    let jobs = env::serve_jobs(160).max(8);
    eprintln!(
        "serve_throughput: {jobs} jobs, sizes {sizes:?} \
         (set PALLAS_SERVE_JOBS / PALLAS_SERVE_SIZES to change)"
    );

    let mut rng = Rng::new(0x5E12E);
    let distinct = jobs.min(48);
    let pool: Vec<Pencil> =
        (0..distinct).map(|i| random_pencil(sizes[i % sizes.len()], &mut rng)).collect();

    // ---- Hard parity gate: served results are bitwise the oracle, both
    // on the cold path and on the cache hit path. ----
    {
        let queue = SubmitQueue::new(ShardRouter::new(serve_cfg(3, 1, 64)).unwrap());
        let handle = queue.handle();
        for p in pool.iter().take(5) {
            for round in 0..2 {
                let d = handle.submit(p.a.clone(), p.b.clone()).unwrap().wait().unwrap();
                let eff = base_cfg().clipped_for(p.n());
                let oracle = reduce_seq(&p.a, &p.b, &eff).unwrap();
                assert_eq!(max_abs_diff(&d.h, &oracle.h), 0.0, "serve H diverges (r{round})");
                assert_eq!(max_abs_diff(&d.t, &oracle.t), 0.0, "serve T diverges (r{round})");
                assert_eq!(max_abs_diff(&d.q, &oracle.q), 0.0, "serve Q diverges (r{round})");
                assert_eq!(max_abs_diff(&d.z, &oracle.z), 0.0, "serve Z diverges (r{round})");
            }
        }
        let c = queue.router().stats().cache.expect("cache configured");
        assert_eq!(c.hits, 5, "second round must be served from the cache");
        queue.shutdown();
    }

    // ---- Geometry sweep (cache off: isolate shard scaling). ----
    println!("{:<8}{:>9}{:>8}{:>12}{:>16}", "shards", "threads", "jobs", "secs", "pencils/sec");
    let mut geom_rows: Vec<GeomRow> = Vec::new();
    for &(shards, threads) in GEOMETRIES {
        let queue = SubmitQueue::new(ShardRouter::new(serve_cfg(shards, threads, 0)).unwrap());
        flood(&queue, &pool, jobs.min(32)); // warmup
        let secs = flood(&queue, &pool, jobs);
        queue.shutdown();
        let pps = jobs as f64 / secs;
        println!("{shards:<8}{threads:>9}{jobs:>8}{secs:>12.4}{pps:>16.1}");
        geom_rows.push(GeomRow { shards, threads, jobs, secs, pencils_per_sec: pps });
    }

    // ---- Cache hit-rate sweep (fixed 2×1 geometry, ample cache). ----
    println!("\n{:<8}{:>8}{:>8}{:>8}{:>10}{:>12}{:>16}", "unique", "jobs", "hits", "miss", "hitrate", "secs", "pencils/sec");
    let mut cache_rows: Vec<CacheRow> = Vec::new();
    for divisor in [1usize, 4, 16] {
        let unique = (distinct / divisor).max(1);
        let queue = SubmitQueue::new(ShardRouter::new(serve_cfg(2, 1, 4096)).unwrap());
        let secs = flood(&queue, &pool[..unique], jobs);
        let stats = queue.router().stats().cache.expect("cache configured");
        queue.shutdown();
        // Structural counter contract (hard): every distinct pencil
        // misses exactly once, every repeat hits.
        assert_eq!(stats.misses, unique as u64, "one miss per distinct pencil");
        assert_eq!(stats.hits, (jobs - unique) as u64, "every repeat hits");
        assert_eq!(stats.evictions, 0, "ample cache must not evict");
        let pps = jobs as f64 / secs;
        let rate = stats.hit_rate();
        println!(
            "{unique:<8}{jobs:>8}{:>8}{:>8}{rate:>10.3}{secs:>12.4}{pps:>16.1}",
            stats.hits, stats.misses
        );
        cache_rows.push(CacheRow {
            unique,
            jobs,
            hits: stats.hits,
            misses: stats.misses,
            hit_rate: rate,
            secs,
            pencils_per_sec: pps,
        });
    }

    // Shape condition (timing-sensitive): the best multi-shard geometry
    // must not be slower than single-shard. Evaluated here, asserted
    // after the JSON artifact is written.
    let pps_single = geom_rows
        .iter()
        .find(|r| r.shards == 1 && r.threads == 1)
        .map(|r| r.pencils_per_sec)
        .unwrap_or(f64::NAN);
    let pps_best_multi = geom_rows
        .iter()
        .filter(|r| r.shards > 1)
        .map(|r| r.pencils_per_sec)
        .fold(f64::NAN, f64::max);
    let speedup_shards = pps_best_multi / pps_single;
    let cond_shards = speedup_shards >= 1.0 / common::bench_tol();

    // ---- Emit BENCH_serve.json. ----
    let mut body = String::new();
    let _ = writeln!(body, "  \"jobs\": {jobs},");
    let _ = writeln!(body, "  \"sizes\": {sizes:?},");
    body.push_str("  \"geometry\": [\n");
    for (i, r) in geom_rows.iter().enumerate() {
        let _ = write!(
            body,
            "    {{\"shards\": {}, \"threads\": {}, \"jobs\": {}, \"secs\": {:.6}, \
             \"pencils_per_sec\": {}}}",
            r.shards,
            r.threads,
            r.jobs,
            r.secs,
            common::json_num(r.pencils_per_sec)
        );
        body.push_str(if i + 1 < geom_rows.len() { ",\n" } else { "\n" });
    }
    body.push_str("  ],\n");
    body.push_str("  \"cache_sweep\": [\n");
    for (i, r) in cache_rows.iter().enumerate() {
        let _ = write!(
            body,
            "    {{\"unique\": {}, \"jobs\": {}, \"hits\": {}, \"misses\": {}, \
             \"hit_rate\": {}, \"secs\": {:.6}, \"pencils_per_sec\": {}}}",
            r.unique,
            r.jobs,
            r.hits,
            r.misses,
            common::json_num(r.hit_rate),
            r.secs,
            common::json_num(r.pencils_per_sec)
        );
        body.push_str(if i + 1 < cache_rows.len() { ",\n" } else { "\n" });
    }
    body.push_str("  ],\n");
    let _ = writeln!(body, "  \"speedup_shards\": {},", common::json_num(speedup_shards));
    let _ = write!(body, "  \"checks_held\": {cond_shards}");
    common::write_bench_json("BENCH_serve.json", "serve_throughput", &body);

    if common::bench_check(
        cond_shards,
        &format!(
            "multi-shard serving must not trail single-shard: best {pps_best_multi:.1} vs \
             {pps_single:.1} pencils/sec"
        ),
    ) {
        println!("\nshape checks OK (serve parity exact; cache counters exact; sharding no slower)");
    }
}
