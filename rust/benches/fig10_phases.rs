//! Bench: regenerate Fig. 10 — parallel speedup and relative runtime of
//! the two phases of ParaHT.
//!
//! Paper shape: most runtime in phase 2 despite phase 1 having slightly
//! more flops; phase speedups track each other; larger matrices scale
//! better (speedup ~2 at n=1000, ~10 at n=8000).
//!
//! Writes `BENCH_fig10.json` (override: `PARAHT_BENCH_OUT`) for the CI
//! perf trajectory — before the shape assertion, so a hard-mode failure
//! never discards the data.

use paraht::experiments::{common, figures};
use paraht::util::env;
use std::fmt::Write as _;

fn main() {
    let sizes = env::bench_sizes(&[192, 384, 576]);
    eprintln!("fig10: sizes {sizes:?}");
    let data = figures::fig10(&sizes, 42);

    for d in &data {
        let header: Vec<String> = common::PAPER_THREADS.iter().map(|p| format!("P={p}")).collect();
        let rows = vec![
            ("stage 1 speedup".to_string(), d.speedups.iter().map(|x| x.1).collect()),
            ("stage 2 speedup".to_string(), d.speedups.iter().map(|x| x.2).collect()),
            ("total speedup".to_string(), d.speedups.iter().map(|x| x.3).collect()),
        ];
        common::print_table(&format!("Fig 10 — phase speedups, n={}", d.n), &header, &rows);
        println!(
            "relative runtime: stage1 {:.1}%  stage2 {:.1}%",
            100.0 * d.stage1_fraction,
            100.0 * d.stage2_fraction
        );
    }

    // Shape: scaling improves (or at least holds) with n. Timing-sensitive:
    // soft mode / PALLAS_BENCH_TOL relax it on noisy hardware.
    let total_last = |d: &figures::PhaseData| d.speedups.last().unwrap().3;
    let mut cond_scales = true;
    let mut msg = String::new();
    if data.len() >= 2 {
        let s_small = total_last(&data[0]);
        let s_big = total_last(data.last().unwrap());
        cond_scales = s_big >= s_small * 0.9 / common::bench_tol();
        msg = format!("larger n should scale at least as well: {s_small:.2} vs {s_big:.2}");
    }

    // ---- Emit BENCH_fig10.json. ----
    let mut body = String::new();
    body.push_str("  \"sizes\": [\n");
    for (i, d) in data.iter().enumerate() {
        let _ = write!(
            body,
            "    {{\"n\": {}, \"stage1_fraction\": {}, \"stage2_fraction\": {}, \"speedups\": [",
            d.n,
            common::json_num(d.stage1_fraction),
            common::json_num(d.stage2_fraction)
        );
        for (j, &(p, s1, s2, tot)) in d.speedups.iter().enumerate() {
            let _ = write!(
                body,
                "{}[{p}, {}, {}, {}]",
                if j > 0 { ", " } else { "" },
                common::json_num(s1),
                common::json_num(s2),
                common::json_num(tot)
            );
        }
        body.push_str(if i + 1 < data.len() { "]},\n" } else { "]}\n" });
    }
    body.push_str("  ],\n");
    let _ = write!(body, "  \"checks_held\": {cond_scales}");
    common::write_bench_json("BENCH_fig10.json", "fig10_phases", &body);

    if !cond_scales {
        common::bench_check(false, &msg);
    } else {
        println!("\nshape checks OK");
    }
}
