//! Bench: regenerate Fig. 10 — parallel speedup and relative runtime of
//! the two phases of ParaHT.
//!
//! Paper shape: most runtime in phase 2 despite phase 1 having slightly
//! more flops; phase speedups track each other; larger matrices scale
//! better (speedup ~2 at n=1000, ~10 at n=8000).

use paraht::experiments::{common, figures};

fn main() {
    let sizes: Vec<usize> = std::env::var("PARAHT_BENCH_SIZES")
        .ok()
        .map(|s| s.split(',').filter_map(|p| p.parse().ok()).collect())
        .unwrap_or_else(|| vec![192, 384, 576]);
    eprintln!("fig10: sizes {sizes:?}");
    let data = figures::fig10(&sizes, 42);

    for d in &data {
        let header: Vec<String> = common::PAPER_THREADS.iter().map(|p| format!("P={p}")).collect();
        let rows = vec![
            ("stage 1 speedup".to_string(), d.speedups.iter().map(|x| x.1).collect()),
            ("stage 2 speedup".to_string(), d.speedups.iter().map(|x| x.2).collect()),
            ("total speedup".to_string(), d.speedups.iter().map(|x| x.3).collect()),
        ];
        common::print_table(&format!("Fig 10 — phase speedups, n={}", d.n), &header, &rows);
        println!(
            "relative runtime: stage1 {:.1}%  stage2 {:.1}%",
            100.0 * d.stage1_fraction,
            100.0 * d.stage2_fraction
        );
    }

    // Shape: scaling improves (or at least holds) with n. Timing-sensitive:
    // soft mode / PALLAS_BENCH_TOL relax it on noisy hardware.
    let total_last = |d: &figures::PhaseData| d.speedups.last().unwrap().3;
    let mut ok = true;
    if data.len() >= 2 {
        let s_small = total_last(&data[0]);
        let s_big = total_last(data.last().unwrap());
        ok = common::bench_check(
            s_big >= s_small * 0.9 / common::bench_tol(),
            &format!("larger n should scale at least as well: {s_small:.2} vs {s_big:.2}"),
        );
    }
    if ok {
        println!("\nshape checks OK");
    }
}
