//! Bench: the paper's flop-count analysis (§2.2, §3.1) from *measured*
//! counts: stage 1 `(28p+14)/(3(p−1))·n³`, stage 2 `10n³`, one-stage
//! `14n³`, two-stage overhead "more than 40%".

use paraht::experiments::flops_table::{measure, stage1_coeff};
use paraht::util::env;

fn main() {
    let sizes = env::bench_sizes(&[192, 320, 448]);
    let (r, p, q) = (8usize, 4usize, 4usize);
    eprintln!("flop table: sizes {sizes:?}, r={r} p={p} q={q}");
    let rows = measure(&sizes, r, p, q, 42);

    println!("\n== Flop-count table (measured / n^3) ==");
    println!(
        "{:<8}{:>10}{:>10}{:>12}{:>12}{:>12}",
        "n", "stage1", "stage2", "two-stage", "one-stage", "overhead"
    );
    for row in &rows {
        let total = row.stage1 + row.stage2;
        println!(
            "{:<8}{:>10.2}{:>10.2}{:>12.2}{:>12.2}{:>11.0}%",
            row.n,
            row.stage1,
            row.stage2,
            total,
            row.one_stage,
            100.0 * (total / row.one_stage - 1.0)
        );
    }
    println!(
        "paper   {:>10.2}{:>10.2}{:>12.2}{:>12.2}{:>11.0}%   (formulas, p={p})",
        stage1_coeff(p),
        10.0,
        stage1_coeff(p) + 10.0,
        14.0,
        100.0 * ((stage1_coeff(p) + 10.0) / 14.0 - 1.0)
    );

    let last = rows.last().unwrap();
    let overhead = (last.stage1 + last.stage2) / last.one_stage - 1.0;
    assert!(overhead > 0.35, "two-stage overhead must exceed ~40%: {:.0}%", overhead * 100.0);
    println!("\nshape checks OK (overhead {:.0}% > 35%)", overhead * 100.0);
}
