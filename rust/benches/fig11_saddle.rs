//! Bench: regenerate Fig. 11 — ParaHT's speedup over the comparators on
//! saddle-point pencils with 25% infinite eigenvalues.
//!
//! Paper shape: the LAPACK column is unchanged from Fig. 9b (neither
//! algorithm's runtime depends on infinite eigenvalues); the HouseHT
//! advantage grows (it pays iterative refinement); IterHT fails to
//! converge within 10 refinement iterations.

use paraht::experiments::{common, figures};
use paraht::util::env;

fn main() {
    let sizes = env::bench_sizes(&[128, 256, 384]);
    eprintln!("fig11: saddle-point pencils, sizes {sizes:?}");
    let saddle = figures::fig11(&sizes, 28, 42);
    let random = figures::fig9b(&sizes, 28, 42);

    let header = vec!["/LAPACK".to_string(), "/HouseHT".to_string(), "/IterHT".to_string()];
    let trows: Vec<(String, Vec<f64>)> = saddle
        .iter()
        .map(|r| (format!("n={}", r.n), vec![r.over_lapack, r.over_househt, r.over_iterht]))
        .collect();
    common::print_table("Fig 11 — ParaHT speedup over comparators (saddle)", &header, &trows);

    for (s, r) in saddle.iter().zip(&random) {
        assert!(s.over_iterht.is_nan(), "IterHT must fail on saddle pencils (n={})", s.n);
        assert!(s.over_lapack.is_finite() && s.over_lapack > 0.0);
        // HouseHT's refinement *mechanism* fires (hundreds of per-block
        // fallbacks — see examples/saddle_point.rs); its wall-clock cost is
        // muted here because our kernels short-circuit the saddle pencil's
        // exact-zero blocks, where the authors' dense refinement arithmetic
        // does not (EXPERIMENTS.md, Fig. 11 notes). Report the ratio.
        println!(
            "n={}: over_HouseHT saddle {:.2} vs random {:.2}",
            s.n, s.over_househt, r.over_househt
        );
    }
    println!("\nshape checks OK (IterHT fails to converge on every saddle size; ParaHT/LAPACK unaffected)");
}
