//! Bench: throughput of the packed register-tiled GEMM layer — the
//! substrate every trailing update funnels through — by shape, `Trans`
//! combination and thread count, written to `BENCH_gemm.json` so future
//! changes have a perf trajectory to regress against (EXPERIMENTS.md §Perf
//! documents the schema).
//!
//! Since the persistent worker pool landed, the parallel entries run on
//! the process-global team (`coordinator::pool`), and a dedicated sweep
//! pits pooled `gemm_par` against the retired scoped-spawn execution model
//! (fresh threads per call, identical panel split) on every benched shape —
//! the pool must never lose.
//!
//! Since the work-assisting scheduler landed (`coordinator::assist`), a
//! second sweep pits the static one-panel-per-executor split against the
//! dynamic claim-counter drain on the same shapes (`static_vs_assist_4t`
//! in the JSON); assisting must be no slower than static at 4 threads on
//! the largest square shape (soft mode / `PALLAS_BENCH_TOL` apply).
//!
//! Since the runtime-dispatched microkernels landed (`linalg::kernels`),
//! a third sweep times every kernel variant this CPU can run — scalar
//! always, AVX2+FMA / NEON when available — on the square sizes
//! sequentially and at 4 threads on the largest (`kernels` in the JSON,
//! with a GFLOP/s column per entry). The SIMD variant must be no slower
//! than scalar on the largest sequential square shape (soft mode /
//! `PALLAS_BENCH_TOL` apply). The sweep forces each variant via
//! `kernels::with_kernel`, bypassing `PALLAS_KERNEL` — which still
//! selects the kernel for every *other* section of this bench.
//!
//! Env knobs (canonical `PALLAS_` names; legacy `PARAHT_` aliases accepted
//! — see `util::env`):
//! * `PALLAS_GEMM_SIZES=128,256,512` — square sizes to sweep (default).
//! * `PALLAS_KERNEL=scalar|avx2|neon|auto` — microkernel for the
//!   non-kernel-sweep sections (`linalg::kernels`).
//! * `PALLAS_BENCH_OUT=path` — JSON output path (default `BENCH_gemm.json`
//!   in the working directory, i.e. `rust/` under `cargo bench`).
//! * `PALLAS_POOL_THREADS` — worker-team size (see `coordinator::pool`).
//! * `PALLAS_BENCH_SOFT=1` / `PALLAS_BENCH_TOL` — soften / relax the
//!   parallel-speedup floor and the pooled-vs-scoped comparison (see
//!   `experiments::common`).

use paraht::coordinator::assist::Schedule;
use paraht::coordinator::slices::partition;
use paraht::experiments::common;
use paraht::linalg::gemm::{gemm, gemm_par, gemm_par_sched, Trans};
use paraht::linalg::kernels::{self, Kernel};
use paraht::linalg::matrix::Matrix;
use paraht::util::flops;
use paraht::util::rng::Rng;
use std::fmt::Write as _;
use std::time::Instant;

/// Thread counts recorded for the parallel sweep (subset of the paper's
/// Fig. 9a axis that fits CI runners).
const THREADS: &[usize] = &[1, 2, 4, 7];

/// Thread count for the pooled-vs-scoped acceptance sweep.
const VS_THREADS: usize = 4;

struct Case {
    m: usize,
    n: usize,
    k: usize,
    trans: &'static str,
    threads: usize,
    secs: f64,
    gflops: f64,
}

fn trans_label(ta: Trans, tb: Trans) -> &'static str {
    match (ta, tb) {
        (Trans::No, Trans::No) => "NN",
        (Trans::Yes, Trans::No) => "TN",
        (Trans::No, Trans::Yes) => "NT",
        (Trans::Yes, Trans::Yes) => "TT",
    }
}

/// The retired pre-pool execution model, kept as the perf baseline: the
/// exact column-panel split of `gemm_par`, executed by freshly spawned
/// scoped threads — per-call thread startup, cold per-thread pack buffers.
fn gemm_scoped_baseline(
    a: &Matrix,
    ta: Trans,
    b: &Matrix,
    tb: Trans,
    c: &mut Matrix,
    threads: usize,
) {
    let n = c.cols();
    let k = if ta == Trans::No { a.cols() } else { a.rows() };
    let panels = partition(0..n, threads);
    let mut work = Vec::with_capacity(panels.len());
    let mut rest = c.as_mut();
    let mut consumed = 0;
    for r in panels {
        let (panel, right) = rest.split_at_col(r.end - consumed);
        consumed = r.end;
        rest = right;
        let bp = match tb {
            Trans::No => b.as_ref().sub(0..k, r),
            Trans::Yes => b.as_ref().sub(r, 0..k),
        };
        work.push((panel, bp));
    }
    std::thread::scope(|s| {
        for (panel, bp) in work {
            let av = a.as_ref();
            s.spawn(move || gemm(1.0, av, ta, bp, tb, 0.0, panel));
        }
    });
}

/// Best-of-3 wall-clock of one multiply (result kept alive via the output
/// matrix norm so the kernel cannot be optimized away).
fn time_gemm(
    a: &Matrix,
    ta: Trans,
    b: &Matrix,
    tb: Trans,
    m: usize,
    n: usize,
    threads: usize,
) -> f64 {
    let mut c = Matrix::zeros(m, n);
    let mut best = f64::INFINITY;
    // One warmup + 3 timed reps.
    for rep in 0..4 {
        let t = Instant::now();
        if threads <= 1 {
            gemm(1.0, a.as_ref(), ta, b.as_ref(), tb, 0.0, c.as_mut());
        } else {
            gemm_par(1.0, a.as_ref(), ta, b.as_ref(), tb, 0.0, c.as_mut(), threads);
        }
        let secs = t.elapsed().as_secs_f64();
        if rep > 0 {
            best = best.min(secs);
        }
    }
    assert!(c.norm_fro().is_finite(), "gemm produced non-finite output");
    best
}

/// Best-of-3 wall-clock of the pooled multiply under an explicit schedule
/// (static panel split vs work-assisting claim counter), bypassing the
/// `PALLAS_ASSIST` process default so both arms measure what they claim.
fn time_gemm_sched(
    a: &Matrix,
    ta: Trans,
    b: &Matrix,
    tb: Trans,
    m: usize,
    n: usize,
    threads: usize,
    sched: Schedule,
) -> f64 {
    let mut c = Matrix::zeros(m, n);
    let mut best = f64::INFINITY;
    for rep in 0..4 {
        let t = Instant::now();
        gemm_par_sched(1.0, a.as_ref(), ta, b.as_ref(), tb, 0.0, c.as_mut(), threads, sched);
        let secs = t.elapsed().as_secs_f64();
        if rep > 0 {
            best = best.min(secs);
        }
    }
    assert!(c.norm_fro().is_finite(), "scheduled gemm produced non-finite output");
    best
}

/// Best-of-3 wall-clock of the scoped-spawn baseline on the same multiply.
fn time_scoped(
    a: &Matrix,
    ta: Trans,
    b: &Matrix,
    tb: Trans,
    m: usize,
    n: usize,
    threads: usize,
) -> f64 {
    let mut c = Matrix::zeros(m, n);
    let mut best = f64::INFINITY;
    for rep in 0..4 {
        let t = Instant::now();
        gemm_scoped_baseline(a, ta, b, tb, &mut c, threads);
        let secs = t.elapsed().as_secs_f64();
        if rep > 0 {
            best = best.min(secs);
        }
    }
    assert!(c.norm_fro().is_finite(), "scoped gemm produced non-finite output");
    best
}

fn run_case(
    cases: &mut Vec<Case>,
    rng: &mut Rng,
    (m, n, k): (usize, usize, usize),
    ta: Trans,
    tb: Trans,
    threads: usize,
) -> f64 {
    let a = if ta == Trans::No { Matrix::randn(m, k, rng) } else { Matrix::randn(k, m, rng) };
    let b = if tb == Trans::No { Matrix::randn(k, n, rng) } else { Matrix::randn(n, k, rng) };
    let secs = time_gemm(&a, ta, &b, tb, m, n, threads);
    let gflops = 2.0 * (m as f64) * (n as f64) * (k as f64) / secs / 1e9;
    let trans = trans_label(ta, tb);
    println!("{m:>5} x {n:<5} k={k:<5} {trans}  threads={threads}  {secs:>9.4}s  {gflops:>7.2} GFLOP/s");
    cases.push(Case { m, n, k, trans, threads, secs, gflops });
    secs
}

struct VsCase {
    m: usize,
    n: usize,
    k: usize,
    trans: &'static str,
    pooled_secs: f64,
    scoped_secs: f64,
}

struct SchedCase {
    m: usize,
    n: usize,
    k: usize,
    trans: &'static str,
    static_secs: f64,
    assist_secs: f64,
}

struct KernelCase {
    kernel: &'static str,
    m: usize,
    n: usize,
    k: usize,
    threads: usize,
    secs: f64,
    gflops: f64,
}

fn main() {
    flops::set_enabled(false); // measure the kernel, not the counter
    let sizes = paraht::util::env::gemm_sizes(&[128, 256, 512]);
    eprintln!("gemm kernels: square sizes {sizes:?} (set PALLAS_GEMM_SIZES to change)");
    let mut rng = Rng::new(4242);
    let mut cases: Vec<Case> = Vec::new();

    // Sequential sweep: square NN at every size, all four Trans combos at
    // the middle size, plus the WY-apply shapes (inner dim = r = 16) and a
    // tall-skinny panel-update shape.
    for &s in &sizes {
        run_case(&mut cases, &mut rng, (s, s, s), Trans::No, Trans::No, 1);
    }
    let mid = sizes[sizes.len() / 2];
    for &(ta, tb) in &[(Trans::Yes, Trans::No), (Trans::No, Trans::Yes), (Trans::Yes, Trans::Yes)] {
        run_case(&mut cases, &mut rng, (mid, mid, mid), ta, tb, 1);
    }
    let wy = sizes.last().copied().unwrap_or(512);
    run_case(&mut cases, &mut rng, (16, wy, wy), Trans::Yes, Trans::No, 1); // X = Vᵀ C
    run_case(&mut cases, &mut rng, (wy, wy, 16), Trans::No, Trans::No, 1); // C -= V X
    run_case(&mut cases, &mut rng, (2048.min(4 * wy), 64, 64), Trans::No, Trans::No, 1);

    // Parallel sweep at the largest size.
    let big = sizes.last().copied().unwrap_or(512);
    let mut t1 = f64::NAN;
    let mut speedups: Vec<(usize, f64)> = Vec::new();
    for &th in THREADS {
        let secs = run_case(&mut cases, &mut rng, (big, big, big), Trans::No, Trans::No, th);
        if th == 1 {
            t1 = secs;
        } else {
            speedups.push((th, t1 / secs));
        }
    }
    for &(th, s) in &speedups {
        println!("gemm_par n={big}: {th} threads -> {s:.2}x over 1 thread");
    }

    // ---- Pooled vs scoped-spawn baseline, every benched shape. ----
    // The persistent pool replaced per-call scoped spawning; it must be no
    // slower on any shape at 4 threads (modest 10% noise slack ×
    // PALLAS_BENCH_TOL; soft mode warns instead of aborting).
    let vs_shapes: Vec<(usize, usize, usize, Trans, Trans)> = {
        let mut v: Vec<_> = sizes.iter().map(|&s| (s, s, s, Trans::No, Trans::No)).collect();
        v.push((16, wy, wy, Trans::Yes, Trans::No));
        v.push((wy, wy, 16, Trans::No, Trans::No));
        v.push((2048.min(4 * wy), 64, 64, Trans::No, Trans::No));
        v
    };
    let mut vs_cases: Vec<VsCase> = Vec::new();
    let mut vs_fail: Vec<String> = Vec::new();
    let vs_slack = 1.10 * common::bench_tol();
    println!("\npooled gemm_par vs scoped-spawn baseline ({VS_THREADS} threads):");
    for &(m, n, k, ta, tb) in &vs_shapes {
        let a = if ta == Trans::No {
            Matrix::randn(m, k, &mut rng)
        } else {
            Matrix::randn(k, m, &mut rng)
        };
        let b = if tb == Trans::No {
            Matrix::randn(k, n, &mut rng)
        } else {
            Matrix::randn(n, k, &mut rng)
        };
        let pooled = time_gemm(&a, ta, &b, tb, m, n, VS_THREADS);
        let scoped = time_scoped(&a, ta, &b, tb, m, n, VS_THREADS);
        let trans = trans_label(ta, tb);
        let ratio = pooled / scoped;
        println!(
            "{m:>5} x {n:<5} k={k:<5} {trans}  pooled {pooled:>9.4}s  scoped {scoped:>9.4}s  ratio {ratio:>5.2}"
        );
        if pooled > scoped * vs_slack {
            vs_fail.push(format!(
                "pooled gemm_par slower than scoped spawn on {m}x{n}x{k} {trans}: \
                 {pooled:.4}s vs {scoped:.4}s (ratio {ratio:.2} > {vs_slack:.2})"
            ));
        }
        vs_cases.push(VsCase { m, n, k, trans, pooled_secs: pooled, scoped_secs: scoped });
    }
    let pooled_ok = vs_fail.is_empty();

    // ---- Static vs work-assisting schedule, same shapes, same team. ----
    // Dynamic oversplits the column panels (~4× the thread count, floor
    // 2·NR columns) and lets workers claim them from an atomic counter;
    // the claim overhead must be paid for by better load balance. The
    // acceptance bar is on the largest square shape only (small shapes sit
    // near the sequential-fallback threshold, where a ~µs claim loop is
    // noise-dominated); all shapes are recorded for the trajectory.
    let mut assist_cases: Vec<SchedCase> = Vec::new();
    let mut assist_ok = true;
    let assist_slack = 1.10 * common::bench_tol();
    let mut assist_msg = String::new();
    println!("\nstatic vs work-assisting gemm_par_sched ({VS_THREADS} threads):");
    for &(m, n, k, ta, tb) in &vs_shapes {
        let a = if ta == Trans::No {
            Matrix::randn(m, k, &mut rng)
        } else {
            Matrix::randn(k, m, &mut rng)
        };
        let b = if tb == Trans::No {
            Matrix::randn(k, n, &mut rng)
        } else {
            Matrix::randn(n, k, &mut rng)
        };
        let st = time_gemm_sched(&a, ta, &b, tb, m, n, VS_THREADS, Schedule::Static);
        let dy = time_gemm_sched(&a, ta, &b, tb, m, n, VS_THREADS, Schedule::Dynamic);
        let trans = trans_label(ta, tb);
        let ratio = dy / st;
        println!(
            "{m:>5} x {n:<5} k={k:<5} {trans}  static {st:>9.4}s  assist {dy:>9.4}s  ratio {ratio:>5.2}"
        );
        if m == big && n == big && k == big && dy > st * assist_slack {
            assist_ok = false;
            assist_msg = format!(
                "assisting gemm slower than static on the largest shape {m}x{n}x{k}: \
                 {dy:.4}s vs {st:.4}s (ratio {ratio:.2} > {assist_slack:.2})"
            );
        }
        assist_cases.push(SchedCase { m, n, k, trans, static_secs: st, assist_secs: dy });
    }

    // ---- Per-kernel-variant sweep (scalar vs AVX2 / NEON). ----
    // Forces each variant this CPU can run via `kernels::with_kernel`
    // (thread-local install; the pool's batch capture propagates it to the
    // workers, so the 4-thread entry exercises the same inheritance path
    // production batches do). `all_available()` lists scalar first, so the
    // scalar baseline time is recorded before any SIMD variant is compared
    // against it. Acceptance: no SIMD variant may be slower than scalar on
    // the largest sequential square shape (10% noise slack ×
    // PALLAS_BENCH_TOL; soft mode warns instead of aborting).
    let variants = Kernel::all_available();
    let mut kernel_cases: Vec<KernelCase> = Vec::new();
    let mut scalar_big = f64::NAN;
    let mut kernel_ok = true;
    let mut kernel_msg = String::new();
    let kernel_slack = 1.10 * common::bench_tol();
    let names: Vec<&str> = variants.iter().map(|kv| kv.name()).collect();
    println!("\nkernel variants on this CPU: {}", names.join(", "));
    for &kv in &variants {
        for &s in &sizes {
            let a = Matrix::randn(s, s, &mut rng);
            let b = Matrix::randn(s, s, &mut rng);
            let secs =
                kernels::with_kernel(kv, || time_gemm(&a, Trans::No, &b, Trans::No, s, s, 1));
            let gflops = 2.0 * (s as f64).powi(3) / secs / 1e9;
            println!(
                "{:>6}  {s:>5} x {s:<5} k={s:<5} NN  threads=1  {secs:>9.4}s  {gflops:>7.2} GFLOP/s",
                kv.name()
            );
            kernel_cases.push(KernelCase { kernel: kv.name(), m: s, n: s, k: s, threads: 1, secs, gflops });
            if s == big {
                if kv == Kernel::Scalar {
                    scalar_big = secs;
                } else if secs > scalar_big * kernel_slack {
                    kernel_ok = false;
                    kernel_msg = format!(
                        "{} kernel slower than scalar on {big}x{big}x{big}: \
                         {secs:.4}s vs {scalar_big:.4}s (ratio {:.2} > {kernel_slack:.2})",
                        kv.name(),
                        secs / scalar_big
                    );
                }
            }
        }
        // One pooled entry at the largest size per variant: the batch
        // captures the submitting thread's kernel, so this pins (and
        // prices) the worker-inheritance path, not just the math.
        let a = Matrix::randn(big, big, &mut rng);
        let b = Matrix::randn(big, big, &mut rng);
        let secs = kernels::with_kernel(kv, || {
            time_gemm(&a, Trans::No, &b, Trans::No, big, big, VS_THREADS)
        });
        let gflops = 2.0 * (big as f64).powi(3) / secs / 1e9;
        println!(
            "{:>6}  {big:>5} x {big:<5} k={big:<5} NN  threads={VS_THREADS}  {secs:>9.4}s  {gflops:>7.2} GFLOP/s",
            kv.name()
        );
        kernel_cases.push(KernelCase {
            kernel: kv.name(),
            m: big,
            n: big,
            k: big,
            threads: VS_THREADS,
            secs,
            gflops,
        });
    }

    // Acceptance floor: ≥ 2× at 4 threads for the n=512-class multiply.
    // Timing-sensitive — soft mode / PALLAS_BENCH_TOL apply (CI runners
    // may have fewer than 4 physical cores). Evaluated here but asserted
    // only AFTER the JSON is written, so a hard-mode failure never
    // discards the measurement run.
    let s4 = speedups.iter().find(|&&(th, _)| th == 4).map(|&(_, s)| s).unwrap_or(f64::NAN);
    let ok = s4 >= 2.0 / common::bench_tol();

    // ---- Emit BENCH_gemm.json (schema in EXPERIMENTS.md §Perf; shared
    // envelope via common::write_bench_json like the fig artifacts). ----
    let mut j = String::new();
    j.push_str("  \"cases\": [\n");
    for (i, c) in cases.iter().enumerate() {
        let _ = write!(
            j,
            "    {{\"m\": {}, \"n\": {}, \"k\": {}, \"trans\": \"{}\", \"threads\": {}, \"secs\": {:.6}, \"gflops\": {:.3}}}",
            c.m, c.n, c.k, c.trans, c.threads, c.secs, c.gflops
        );
        j.push_str(if i + 1 < cases.len() { ",\n" } else { "\n" });
    }
    j.push_str("  ],\n");
    let _ = write!(j, "  \"pooled_vs_scoped_{VS_THREADS}t\": [\n");
    for (i, c) in vs_cases.iter().enumerate() {
        let _ = write!(
            j,
            "    {{\"m\": {}, \"n\": {}, \"k\": {}, \"trans\": \"{}\", \"pooled_secs\": {:.6}, \"scoped_secs\": {:.6}, \"ratio\": {:.4}}}",
            c.m, c.n, c.k, c.trans, c.pooled_secs, c.scoped_secs, c.pooled_secs / c.scoped_secs
        );
        j.push_str(if i + 1 < vs_cases.len() { ",\n" } else { "\n" });
    }
    j.push_str("  ],\n");
    let _ = writeln!(j, "  \"pooled_no_slower_held\": {pooled_ok},");
    let _ = write!(j, "  \"static_vs_assist_{VS_THREADS}t\": [\n");
    for (i, c) in assist_cases.iter().enumerate() {
        let _ = write!(
            j,
            "    {{\"m\": {}, \"n\": {}, \"k\": {}, \"trans\": \"{}\", \"static_secs\": {:.6}, \"assist_secs\": {:.6}, \"ratio\": {:.4}}}",
            c.m, c.n, c.k, c.trans, c.static_secs, c.assist_secs, c.assist_secs / c.static_secs
        );
        j.push_str(if i + 1 < assist_cases.len() { ",\n" } else { "\n" });
    }
    j.push_str("  ],\n");
    let _ = writeln!(j, "  \"assist_no_slower_held\": {assist_ok},");
    j.push_str("  \"kernels\": [\n");
    for (i, c) in kernel_cases.iter().enumerate() {
        let _ = write!(
            j,
            "    {{\"kernel\": \"{}\", \"m\": {}, \"n\": {}, \"k\": {}, \"threads\": {}, \"secs\": {:.6}, \"gflops\": {:.3}}}",
            c.kernel, c.m, c.n, c.k, c.threads, c.secs, c.gflops
        );
        j.push_str(if i + 1 < kernel_cases.len() { ",\n" } else { "\n" });
    }
    j.push_str("  ],\n");
    let _ = writeln!(j, "  \"simd_no_slower_held\": {kernel_ok},");
    let _ = write!(j, "  \"par_speedup_n{big}\": {{");
    for (i, &(th, s)) in speedups.iter().enumerate() {
        let _ = write!(j, "{}\"x{th}\": {s:.3}", if i > 0 { ", " } else { "" });
    }
    j.push_str("},\n");
    let _ = write!(j, "  \"speedup_floor_held\": {ok}");
    common::write_bench_json("BENCH_gemm.json", "gemm_kernels", &j);
    println!("({} cases)", cases.len());

    common::bench_check(
        ok,
        &format!("gemm_par at 4 threads must be >= 2x single-thread for n={big}: got {s4:.2}x"),
    );
    for msg in &vs_fail {
        common::bench_check(false, msg);
    }
    common::bench_check(assist_ok, &assist_msg);
    common::bench_check(kernel_ok, &kernel_msg);
    if ok {
        println!("shape checks OK (gemm_par 4-thread speedup {s4:.2}x >= 2x)");
    }
    if pooled_ok {
        println!("pooled-vs-scoped OK (pool no slower on all {} shapes)", vs_cases.len());
    }
    if assist_ok {
        println!(
            "static-vs-assist OK (assisting no slower at {VS_THREADS} threads on n={big})"
        );
    }
    if kernel_ok {
        println!(
            "kernel variants OK ({} no slower than scalar on n={big})",
            names.join("/")
        );
    }
}
