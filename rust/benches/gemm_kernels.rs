//! Bench: throughput of the packed register-tiled GEMM layer — the
//! substrate every trailing update funnels through — by shape, `Trans`
//! combination and thread count, written to `BENCH_gemm.json` so future
//! changes have a perf trajectory to regress against (EXPERIMENTS.md §Perf
//! documents the schema).
//!
//! Env knobs:
//! * `PARAHT_GEMM_SIZES=128,256,512` — square sizes to sweep (default).
//! * `PARAHT_BENCH_OUT=path` — JSON output path (default `BENCH_gemm.json`
//!   in the working directory, i.e. `rust/` under `cargo bench`).
//! * `PALLAS_BENCH_SOFT=1` / `PALLAS_BENCH_TOL` — soften / relax the
//!   parallel-speedup floor (see `experiments::common`).

use paraht::experiments::common;
use paraht::linalg::gemm::{gemm, gemm_par, Trans};
use paraht::linalg::matrix::Matrix;
use paraht::util::flops;
use paraht::util::rng::Rng;
use std::fmt::Write as _;
use std::time::Instant;

/// Thread counts recorded for the parallel sweep (subset of the paper's
/// Fig. 9a axis that fits CI runners).
const THREADS: &[usize] = &[1, 2, 4, 7];

struct Case {
    m: usize,
    n: usize,
    k: usize,
    trans: &'static str,
    threads: usize,
    secs: f64,
    gflops: f64,
}

/// Best-of-3 wall-clock of one multiply (result kept alive via the output
/// matrix norm so the kernel cannot be optimized away).
fn time_gemm(
    a: &Matrix,
    ta: Trans,
    b: &Matrix,
    tb: Trans,
    m: usize,
    n: usize,
    threads: usize,
) -> f64 {
    let mut c = Matrix::zeros(m, n);
    let mut best = f64::INFINITY;
    // One warmup + 3 timed reps.
    for rep in 0..4 {
        let t = Instant::now();
        if threads <= 1 {
            gemm(1.0, a.as_ref(), ta, b.as_ref(), tb, 0.0, c.as_mut());
        } else {
            gemm_par(1.0, a.as_ref(), ta, b.as_ref(), tb, 0.0, c.as_mut(), threads);
        }
        let secs = t.elapsed().as_secs_f64();
        if rep > 0 {
            best = best.min(secs);
        }
    }
    assert!(c.norm_fro().is_finite(), "gemm produced non-finite output");
    best
}

fn run_case(
    cases: &mut Vec<Case>,
    rng: &mut Rng,
    (m, n, k): (usize, usize, usize),
    ta: Trans,
    tb: Trans,
    threads: usize,
) -> f64 {
    let a = if ta == Trans::No { Matrix::randn(m, k, rng) } else { Matrix::randn(k, m, rng) };
    let b = if tb == Trans::No { Matrix::randn(k, n, rng) } else { Matrix::randn(n, k, rng) };
    let secs = time_gemm(&a, ta, &b, tb, m, n, threads);
    let gflops = 2.0 * (m as f64) * (n as f64) * (k as f64) / secs / 1e9;
    let trans = match (ta, tb) {
        (Trans::No, Trans::No) => "NN",
        (Trans::Yes, Trans::No) => "TN",
        (Trans::No, Trans::Yes) => "NT",
        (Trans::Yes, Trans::Yes) => "TT",
    };
    println!("{m:>5} x {n:<5} k={k:<5} {trans}  threads={threads}  {secs:>9.4}s  {gflops:>7.2} GFLOP/s");
    cases.push(Case { m, n, k, trans, threads, secs, gflops });
    secs
}

fn main() {
    flops::set_enabled(false); // measure the kernel, not the counter
    let mut sizes: Vec<usize> = std::env::var("PARAHT_GEMM_SIZES")
        .ok()
        .map(|s| s.split(',').filter_map(|p| p.parse().ok()).collect())
        .unwrap_or_default();
    if sizes.is_empty() {
        sizes = vec![128, 256, 512];
    }
    eprintln!("gemm kernels: square sizes {sizes:?} (set PARAHT_GEMM_SIZES to change)");
    let mut rng = Rng::new(4242);
    let mut cases: Vec<Case> = Vec::new();

    // Sequential sweep: square NN at every size, all four Trans combos at
    // the middle size, plus the WY-apply shapes (inner dim = r = 16) and a
    // tall-skinny panel-update shape.
    for &s in &sizes {
        run_case(&mut cases, &mut rng, (s, s, s), Trans::No, Trans::No, 1);
    }
    let mid = sizes[sizes.len() / 2];
    for &(ta, tb) in &[(Trans::Yes, Trans::No), (Trans::No, Trans::Yes), (Trans::Yes, Trans::Yes)] {
        run_case(&mut cases, &mut rng, (mid, mid, mid), ta, tb, 1);
    }
    let wy = sizes.last().copied().unwrap_or(512);
    run_case(&mut cases, &mut rng, (16, wy, wy), Trans::Yes, Trans::No, 1); // X = Vᵀ C
    run_case(&mut cases, &mut rng, (wy, wy, 16), Trans::No, Trans::No, 1); // C -= V X
    run_case(&mut cases, &mut rng, (2048.min(4 * wy), 64, 64), Trans::No, Trans::No, 1);

    // Parallel sweep at the largest size.
    let big = sizes.last().copied().unwrap_or(512);
    let mut t1 = f64::NAN;
    let mut speedups: Vec<(usize, f64)> = Vec::new();
    for &th in THREADS {
        let secs = run_case(&mut cases, &mut rng, (big, big, big), Trans::No, Trans::No, th);
        if th == 1 {
            t1 = secs;
        } else {
            speedups.push((th, t1 / secs));
        }
    }
    for &(th, s) in &speedups {
        println!("gemm_par n={big}: {th} threads -> {s:.2}x over 1 thread");
    }

    // Acceptance floor: ≥ 2× at 4 threads for the n=512-class multiply.
    // Timing-sensitive — soft mode / PALLAS_BENCH_TOL apply (CI runners
    // may have fewer than 4 physical cores). Evaluated here but asserted
    // only AFTER the JSON is written, so a hard-mode failure never
    // discards the measurement run.
    let s4 = speedups.iter().find(|&&(th, _)| th == 4).map(|&(_, s)| s).unwrap_or(f64::NAN);
    let ok = s4 >= 2.0 / common::bench_tol();

    // ---- Emit BENCH_gemm.json (schema in EXPERIMENTS.md §Perf). ----
    let out_path =
        std::env::var("PARAHT_BENCH_OUT").unwrap_or_else(|_| "BENCH_gemm.json".to_string());
    let mut j = String::new();
    j.push_str("{\n  \"schema_version\": 1,\n  \"bench\": \"gemm_kernels\",\n");
    let _ = writeln!(j, "  \"soft_mode\": {},", common::bench_soft());
    let _ = writeln!(j, "  \"tolerance\": {},", common::bench_tol());
    j.push_str("  \"cases\": [\n");
    for (i, c) in cases.iter().enumerate() {
        let _ = write!(
            j,
            "    {{\"m\": {}, \"n\": {}, \"k\": {}, \"trans\": \"{}\", \"threads\": {}, \"secs\": {:.6}, \"gflops\": {:.3}}}",
            c.m, c.n, c.k, c.trans, c.threads, c.secs, c.gflops
        );
        j.push_str(if i + 1 < cases.len() { ",\n" } else { "\n" });
    }
    j.push_str("  ],\n");
    let _ = write!(j, "  \"par_speedup_n{big}\": {{");
    for (i, &(th, s)) in speedups.iter().enumerate() {
        let _ = write!(j, "{}\"x{th}\": {s:.3}", if i > 0 { ", " } else { "" });
    }
    j.push_str("},\n");
    let _ = writeln!(j, "  \"speedup_floor_held\": {ok}");
    j.push_str("}\n");
    std::fs::write(&out_path, &j).expect("write BENCH_gemm.json");
    println!("\nwrote {out_path} ({} cases)", cases.len());

    common::bench_check(
        ok,
        &format!("gemm_par at 4 threads must be >= 2x single-thread for n={big}: got {s4:.2}x"),
    );
    if ok {
        println!("shape checks OK (gemm_par 4-thread speedup {s4:.2}x >= 2x)");
    }
}
