//! Bench: regenerate Fig. 9b — ParaHT's speedup over LAPACK, HouseHT and
//! IterHT for varying pencil sizes (random pencils, full machine width;
//! comparators capped at 14 threads as in the paper).
//!
//! Paper shape: ~2x over HouseHT; slightly slower than LAPACK for small
//! matrices growing to ~4x for large ones; IterHT ahead except when it
//! needs a second iteration.

use paraht::experiments::{common, figures};

fn main() {
    let sizes: Vec<usize> = std::env::var("PARAHT_BENCH_SIZES")
        .ok()
        .map(|s| s.split(',').filter_map(|p| p.parse().ok()).collect())
        .unwrap_or_else(|| vec![128, 256, 384, 512]);
    eprintln!("fig9b: sizes {sizes:?} (set PARAHT_BENCH_SIZES to change)");
    let rows = figures::fig9b(&sizes, 28, 42);

    let header = vec!["/LAPACK".to_string(), "/HouseHT".to_string(), "/IterHT".to_string()];
    let trows: Vec<(String, Vec<f64>)> = rows
        .iter()
        .map(|r| (format!("n={}", r.n), vec![r.over_lapack, r.over_househt, r.over_iterht]))
        .collect();
    common::print_table("Fig 9b — ParaHT speedup over comparators (random)", &header, &trows);

    // Shape: the advantage over LAPACK grows with n. Timing-sensitive
    // (simulated from measured task durations): soft mode / tolerance
    // envs relax it on noisy hardware.
    let first = rows.first().unwrap().over_lapack;
    let last = rows.last().unwrap().over_lapack;
    if common::bench_check(
        last > first / common::bench_tol(),
        &format!("speedup over LAPACK should grow with n: {first:.2} -> {last:.2}"),
    ) {
        println!("\nshape checks OK (advantage over LAPACK grows with n)");
    }
}
