//! Bench: regenerate Fig. 9b — ParaHT's speedup over LAPACK, HouseHT and
//! IterHT for varying pencil sizes (random pencils, full machine width;
//! comparators capped at 14 threads as in the paper).
//!
//! Paper shape: ~2x over HouseHT; slightly slower than LAPACK for small
//! matrices growing to ~4x for large ones; IterHT ahead except when it
//! needs a second iteration.
//!
//! Writes `BENCH_fig9b.json` (override: `PARAHT_BENCH_OUT`) for the CI
//! perf trajectory — before the shape assertion, so a hard-mode failure
//! never discards the data. Non-finite ratios (IterHT divergence) are
//! recorded as `null`.

use paraht::experiments::{common, figures};
use paraht::util::env;
use std::fmt::Write as _;

fn main() {
    let sizes = env::bench_sizes(&[128, 256, 384, 512]);
    eprintln!("fig9b: sizes {sizes:?} (set PALLAS_BENCH_SIZES to change)");
    let rows = figures::fig9b(&sizes, 28, 42);

    let header = vec!["/LAPACK".to_string(), "/HouseHT".to_string(), "/IterHT".to_string()];
    let trows: Vec<(String, Vec<f64>)> = rows
        .iter()
        .map(|r| (format!("n={}", r.n), vec![r.over_lapack, r.over_househt, r.over_iterht]))
        .collect();
    common::print_table("Fig 9b — ParaHT speedup over comparators (random)", &header, &trows);

    // Shape: the advantage over LAPACK grows with n. Timing-sensitive
    // (simulated from measured task durations): soft mode / tolerance
    // envs relax it on noisy hardware.
    let first = rows.first().unwrap().over_lapack;
    let last = rows.last().unwrap().over_lapack;
    let cond_grows = last > first / common::bench_tol();

    // ---- Emit BENCH_fig9b.json. ----
    let mut body = String::new();
    body.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let _ = write!(
            body,
            "    {{\"n\": {}, \"over_lapack\": {}, \"over_househt\": {}, \"over_iterht\": {}}}",
            r.n,
            common::json_num(r.over_lapack),
            common::json_num(r.over_househt),
            common::json_num(r.over_iterht)
        );
        body.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    body.push_str("  ],\n");
    let _ = write!(body, "  \"checks_held\": {cond_grows}");
    common::write_bench_json("BENCH_fig9b.json", "fig9b_sizes", &body);

    if common::bench_check(
        cond_grows,
        &format!("speedup over LAPACK should grow with n: {first:.2} -> {last:.2}"),
    ) {
        println!("\nshape checks OK (advantage over LAPACK grows with n)");
    }
}
