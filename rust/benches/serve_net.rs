//! Bench: serving front-door overhead — the same mixed-size pencil flood
//! through three doors:
//!
//! * **in-process** — `SubmitHandle` straight into the queue (baseline);
//! * **socket** — the frame protocol over loopback TCP (`NetServer` +
//!   one `NetClient` per client thread), same queue behind it;
//! * **procs** — the `ShardSupervisor`'s per-shard child processes,
//!   frames over stdin/stdout (this bench binary re-invokes itself with
//!   `--shard-worker`, which is why it must be `harness = false`).
//!
//! The cache is disabled everywhere so the numbers isolate transport +
//! process overhead, not memoization. Bitwise parity of every door
//! against the sequential oracle — including band-clip sizes (n ≤ r) —
//! is hard-asserted up front; per-mode p50/p90/p99 latencies come from
//! the serving tier's own log2-bucket histograms.
//!
//! Writes `BENCH_serve_net.json` (override: `PALLAS_BENCH_OUT`) before
//! any timing-sensitive assertion. Env knobs: `PALLAS_SERVE_JOBS`,
//! `PALLAS_SERVE_SIZES`, `PALLAS_BENCH_SOFT`, `PALLAS_BENCH_TOL`.

use paraht::api::reduce_seq;
use paraht::config::Config;
use paraht::experiments::common;
use paraht::ht::two_stage::HtDecomposition;
use paraht::pencil::random::random_pencil;
use paraht::pencil::Pencil;
use paraht::serve::{
    LatencyHistogram, NetClient, NetConfig, NetServer, ServeConfig, ShardRouter, ShardSupervisor,
    SubmitQueue, SupervisorConfig,
};
use paraht::util::env;
use paraht::util::proptest::max_abs_diff;
use paraht::util::rng::Rng;
use std::fmt::Write as _;
use std::time::Instant;

const CLIENTS: usize = 3;

/// Small-pencil serving tuning (band must fit the smallest size).
fn base_cfg() -> Config {
    Config { r: 4, p: 2, q: 4, ..Config::default() }
}

fn serve_cfg() -> ServeConfig {
    ServeConfig {
        shards: 2,
        threads_per_shard: 1,
        cache_entries: 0, // isolate transport overhead, not memoization
        base: base_cfg(),
        ..ServeConfig::default()
    }
}

fn supervisor_cfg() -> SupervisorConfig {
    SupervisorConfig {
        procs: 2,
        threads_per_proc: 1,
        base: base_cfg(),
        // worker_argv stays empty: it resolves to this bench executable
        // plus `--shard-worker`, which `main` handles first thing.
        ..SupervisorConfig::default()
    }
}

/// Hard bitwise gate: `d` must be exactly the sequential oracle under the
/// effective (band-clipped) config.
fn assert_parity(label: &str, p: &Pencil, d: &HtDecomposition) {
    let eff = base_cfg().clipped_for(p.n());
    let oracle = reduce_seq(&p.a, &p.b, &eff).expect("oracle reduction succeeds");
    assert_eq!(max_abs_diff(&d.h, &oracle.h), 0.0, "{label}: H diverges (n={})", p.n());
    assert_eq!(max_abs_diff(&d.t, &oracle.t), 0.0, "{label}: T diverges (n={})", p.n());
    assert_eq!(max_abs_diff(&d.q, &oracle.q), 0.0, "{label}: Q diverges (n={})", p.n());
    assert_eq!(max_abs_diff(&d.z, &oracle.z), 0.0, "{label}: Z diverges (n={})", p.n());
}

struct ModeRow {
    mode: &'static str,
    jobs: usize,
    secs: f64,
    pencils_per_sec: f64,
    p50_ms: f64,
    p90_ms: f64,
    p99_ms: f64,
    mean_ms: f64,
}

fn mode_row(mode: &'static str, jobs: usize, secs: f64, hist: &LatencyHistogram) -> ModeRow {
    let s = hist.snapshot();
    ModeRow {
        mode,
        jobs,
        secs,
        pencils_per_sec: jobs as f64 / secs,
        p50_ms: s.p50_ms(),
        p90_ms: s.p90_ms(),
        p99_ms: s.p99_ms(),
        mean_ms: s.mean_ms(),
    }
}

/// In-process baseline: `CLIENTS` threads submit through clones of one
/// `SubmitHandle` and wait each ticket synchronously.
fn run_in_process(pool: &[Pencil], jobs: usize) -> ModeRow {
    let queue = SubmitQueue::new(ShardRouter::new(serve_cfg()).unwrap());
    let handle = queue.handle();
    for p in pool.iter().take(4) {
        let d = handle.submit(p.a.clone(), p.b.clone()).unwrap().wait().unwrap();
        assert_parity("in_process", p, &d);
    }
    let hist = LatencyHistogram::new();
    let t = Instant::now();
    std::thread::scope(|s| {
        for c in 0..CLIENTS {
            let handle = queue.handle();
            let hist = &hist;
            s.spawn(move || {
                let mut i = c;
                while i < jobs {
                    let p = &pool[i % pool.len()];
                    let t0 = Instant::now();
                    let ticket =
                        handle.submit(p.a.clone(), p.b.clone()).expect("submission accepted");
                    ticket.wait().expect("served reduction succeeds");
                    hist.record(t0.elapsed());
                    i += CLIENTS;
                }
            });
        }
    });
    let secs = t.elapsed().as_secs_f64();
    queue.shutdown();
    mode_row("in_process", jobs, secs, &hist)
}

/// Loopback socket: same queue, but every job is framed, sent, decoded,
/// executed, framed back. One connection per client thread (the server's
/// acceptor pool is sized to match).
fn run_socket(pool: &[Pencil], jobs: usize) -> ModeRow {
    let queue = SubmitQueue::new(ShardRouter::new(serve_cfg()).unwrap());
    let ncfg = NetConfig { addr: "127.0.0.1:0".to_string(), acceptors: CLIENTS };
    let server = NetServer::start(queue, ncfg).expect("bind loopback server");
    let addr = server.addr().to_string();
    {
        let mut client = NetClient::connect(&addr).expect("connect parity client");
        for p in pool.iter().take(4) {
            let d = client.reduce(&p.a, &p.b).expect("socket reduction succeeds");
            assert_parity("socket", p, &d);
        }
    }
    let hist = LatencyHistogram::new();
    let t = Instant::now();
    std::thread::scope(|s| {
        for c in 0..CLIENTS {
            let addr = &addr;
            let hist = &hist;
            s.spawn(move || {
                let mut client = NetClient::connect(addr).expect("connect flood client");
                let mut i = c;
                while i < jobs {
                    let p = &pool[i % pool.len()];
                    let t0 = Instant::now();
                    client.reduce(&p.a, &p.b).expect("socket reduction succeeds");
                    hist.record(t0.elapsed());
                    i += CLIENTS;
                }
            });
        }
    });
    let secs = t.elapsed().as_secs_f64();
    let stats = NetClient::connect(&addr)
        .and_then(|mut c| c.stats())
        .expect("stats over the socket");
    assert!(stats.contains("\"mode\": \"queue\""), "stats JSON names its backend: {stats}");
    server.shutdown();
    mode_row("socket", jobs, secs, &hist)
}

/// Multi-process: per-shard child workers behind the supervisor, frames
/// over stdin/stdout. A healthy flood must never restart a child —
/// hard-asserted via the supervisor's counters.
fn run_procs(pool: &[Pencil], jobs: usize) -> ModeRow {
    let sup = ShardSupervisor::new(supervisor_cfg()).expect("supervisor config valid");
    for p in pool.iter().take(4) {
        let d = sup.reduce(&p.a, &p.b).expect("supervised reduction succeeds");
        assert_parity("procs", p, &d);
    }
    let hist = LatencyHistogram::new();
    let t = Instant::now();
    std::thread::scope(|s| {
        for c in 0..CLIENTS {
            let sup = &sup;
            let hist = &hist;
            s.spawn(move || {
                let mut i = c;
                while i < jobs {
                    let p = &pool[i % pool.len()];
                    let t0 = Instant::now();
                    sup.reduce(&p.a, &p.b).expect("supervised reduction succeeds");
                    hist.record(t0.elapsed());
                    i += CLIENTS;
                }
            });
        }
    });
    let secs = t.elapsed().as_secs_f64();
    let stats = sup.stats();
    assert_eq!(stats.restarts(), 0, "healthy flood must not restart a child");
    sup.shutdown();
    mode_row("procs", jobs, secs, &hist)
}

fn main() {
    // Worker mode first: the supervisor re-invokes this executable with
    // `--shard-worker`, and the worker owns stdin/stdout entirely.
    if std::env::args().any(|a| a == "--shard-worker") {
        std::process::exit(paraht::serve::worker_main());
    }

    let sizes = env::serve_sizes(&[12, 16, 24]);
    let jobs = env::serve_jobs(96).max(CLIENTS);
    eprintln!(
        "serve_net: {jobs} jobs x 3 doors, sizes {sizes:?} \
         (set PALLAS_SERVE_JOBS / PALLAS_SERVE_SIZES to change)"
    );

    let mut rng = Rng::new(0x5E7);
    // The parity prefix (first 4 pool entries, checked by every mode)
    // deliberately includes band-clip sizes n <= r.
    let mut pool: Vec<Pencil> =
        [3usize, 4, 6].iter().map(|&n| random_pencil(n, &mut rng)).collect();
    let distinct = jobs.min(32).max(4);
    pool.extend((0..distinct - 3).map(|i| random_pencil(sizes[i % sizes.len()], &mut rng)));

    let rows = vec![
        run_in_process(&pool, jobs),
        run_socket(&pool, jobs),
        run_procs(&pool, jobs),
    ];
    println!(
        "{:<12}{:>7}{:>10}{:>14}{:>10}{:>10}{:>10}{:>10}",
        "mode", "jobs", "secs", "pencils/sec", "p50ms", "p90ms", "p99ms", "meanms"
    );
    for r in &rows {
        println!(
            "{:<12}{:>7}{:>10.4}{:>14.1}{:>10.2}{:>10.2}{:>10.2}{:>10.2}",
            r.mode, r.jobs, r.secs, r.pencils_per_sec, r.p50_ms, r.p90_ms, r.p99_ms, r.mean_ms
        );
    }

    let pps = |mode: &str| {
        rows.iter().find(|r| r.mode == mode).map(|r| r.pencils_per_sec).unwrap_or(f64::NAN)
    };
    let socket_overhead = pps("in_process") / pps("socket");
    // Timing-sensitive shape condition: loopback framing costs something,
    // but must not eat an order of magnitude on these job sizes. Asserted
    // only after the JSON artifact exists.
    let cond_socket = socket_overhead <= 10.0 * common::bench_tol();

    let mut body = String::new();
    let _ = writeln!(body, "  \"jobs\": {jobs},");
    let _ = writeln!(body, "  \"sizes\": {sizes:?},");
    let _ = writeln!(body, "  \"clients\": {CLIENTS},");
    body.push_str("  \"modes\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let _ = write!(
            body,
            "    {{\"mode\": \"{}\", \"jobs\": {}, \"secs\": {:.6}, \"pencils_per_sec\": {}, \
             \"p50_ms\": {}, \"p90_ms\": {}, \"p99_ms\": {}, \"mean_ms\": {}}}",
            r.mode,
            r.jobs,
            r.secs,
            common::json_num(r.pencils_per_sec),
            common::json_num(r.p50_ms),
            common::json_num(r.p90_ms),
            common::json_num(r.p99_ms),
            common::json_num(r.mean_ms)
        );
        body.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    body.push_str("  ],\n");
    let _ = writeln!(body, "  \"socket_overhead\": {},", common::json_num(socket_overhead));
    let _ = write!(body, "  \"checks_held\": {cond_socket}");
    common::write_bench_json("BENCH_serve_net.json", "serve_net", &body);

    if common::bench_check(
        cond_socket,
        &format!(
            "socket door must stay within 10x of in-process: {:.1} vs {:.1} pencils/sec \
             (overhead {socket_overhead:.2}x)",
            pps("socket"),
            pps("in_process")
        ),
    ) {
        println!("\nshape checks OK (all doors bitwise-exact; socket overhead {socket_overhead:.2}x)");
    }
}
