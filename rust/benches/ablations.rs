//! Bench: ablations over the paper's design choices — the `p` block
//! multiplier (§2.2), the `q` sweep-group size (§3.2), the stage-2
//! lookahead (§3.3), and blocked vs unblocked stage 2 (Alg. 2 vs 3+4).

use paraht::config::Config;
use paraht::experiments::ablations::{lookahead_ablation, p_sweep, q_sweep};
use paraht::experiments::common;
use paraht::util::env;

fn main() {
    let n: usize = env::bench_n(320);
    eprintln!("ablations at n={n}");

    println!("\n== p sweep (stage 1): flops/n^3 and time ==");
    println!("{:<6}{:>10}{:>14}{:>14}", "p", "time[s]", "flops/n^3", "formula");
    for (p, secs, coeff) in p_sweep(n, 8, &[2, 4, 8, 12], 42) {
        let formula = (28.0 * p as f64 + 14.0) / (3.0 * (p as f64 - 1.0));
        println!("{p:<6}{secs:>10.3}{coeff:>14.2}{formula:>14.2}");
    }

    // q sweep at the paper's bandwidth r=16: the WY accumulation only pays
    // off once the reflector groups are wide enough (q·r block updates) —
    // at small r the unblocked Algorithm 2 wins, which is exactly why the
    // paper pairs r=16 with q=8.
    let nq = n.max(512);
    println!("\n== q sweep (stage 2, r=16, n={nq}): sequential time (q=0 → unblocked Alg 2) ==");
    println!("{:<6}{:>10}", "q", "time[s]");
    let rows = q_sweep(nq, 16, &[1, 2, 4, 8, 16], 42);
    for (q, secs) in &rows {
        println!("{q:<6}{secs:>10.3}");
    }
    // Blocked with a reasonable q must beat the unblocked algorithm.
    // Wall-clock comparison — soft mode / PALLAS_BENCH_TOL relax it.
    let tol = common::bench_tol();
    let unblocked = rows[0].1;
    let best_blocked = rows[1..].iter().map(|r| r.1).fold(f64::INFINITY, f64::min);
    let mut ok = common::bench_check(
        best_blocked < unblocked * tol,
        &format!("blocked stage 2 must beat unblocked: {best_blocked:.3}s vs {unblocked:.3}s"),
    );

    println!("\n== lookahead (stage 2, P=14) ==");
    let cfg = Config { r: 8, q: 4, ..Config::default() };
    let (with_look, without) = lookahead_ablation(n, &cfg, 14, 42);
    println!("with lookahead:    {with_look:.4}s");
    println!(
        "without lookahead: {without:.4}s   ({:.1}% slower)",
        100.0 * (without / with_look - 1.0)
    );
    ok &= common::bench_check(
        with_look <= without * 1.02 * tol,
        &format!("lookahead must not hurt: {with_look:.4}s vs {without:.4}s"),
    );

    if ok {
        println!("\nshape checks OK (blocked beats unblocked; lookahead helps)");
    }
}
