//! Bench: regenerate Fig. 9a — parallel speedup (fraction of the
//! single-threaded LAPACK runtime) for a random pencil, as a function of
//! the number of threads.
//!
//! Paper setup: n = 8000 on a 28-core Xeon. Here: a scaled n on measured
//! single-core task costs + the makespan simulator (DESIGN.md §5); the
//! reported quantity is the same *relative* speedup, so the curve shapes
//! are comparable: ParaHT starts below 1 (extra flops) and overtakes the
//! comparators as P grows; HouseHT/IterHT saturate by 14 threads.

use paraht::experiments::{common, figures};

fn main() {
    let n: usize = std::env::var("PARAHT_BENCH_N")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(384);
    eprintln!("fig9a: random pencil n={n} (set PARAHT_BENCH_N to change)");
    let series = figures::fig9a(n, 42);

    let header: Vec<String> = common::PAPER_THREADS.iter().map(|p| format!("P={p}")).collect();
    let rows: Vec<(String, Vec<f64>)> = series
        .iter()
        .map(|s| (s.name.to_string(), s.points.iter().map(|&(_, v)| v).collect()))
        .collect();
    common::print_table(
        &format!("Fig 9a — speedup over sequential LAPACK, random pencil n={n}"),
        &header,
        &rows,
    );

    // Shape assertions (the paper's qualitative claims). Timing-sensitive:
    // soft mode / PALLAS_BENCH_TOL relax them on slow or noisy hardware.
    let tol = common::bench_tol();
    let para = &series[0];
    let p1 = para.points.first().unwrap().1;
    let plast = para.points.last().unwrap().1;
    // The paper's 1-core ParaHT trails LAPACK by the 21.33/14 flop ratio;
    // our WY kernels are per-flop faster than the rotation kernels, so at
    // larger n the ratio can approach (or pass) 1 — warn, don't fail.
    if p1 >= 1.0 {
        println!("note: 1-core ParaHT at {p1:.2}x LAPACK (per-flop kernel advantage offsets the extra flops at this n)");
    }
    let mut ok = common::bench_check(p1 < 1.6 * tol, &format!("1-core ParaHT implausibly fast: {p1:.2}"));
    ok &= common::bench_check(
        plast > p1 * 1.5 / tol,
        &format!("ParaHT must scale with P: {p1:.2} -> {plast:.2}"),
    );
    if ok {
        println!("\nshape checks OK (ParaHT scales with P; comparators saturate)");
    }
}
