//! Bench: regenerate Fig. 9a — parallel speedup (fraction of the
//! single-threaded LAPACK runtime) for a random pencil, as a function of
//! the number of threads.
//!
//! Paper setup: n = 8000 on a 28-core Xeon. Here: a scaled n on measured
//! single-core task costs + the makespan simulator (DESIGN.md §5); the
//! reported quantity is the same *relative* speedup, so the curve shapes
//! are comparable: ParaHT starts below 1 (extra flops) and overtakes the
//! comparators as P grows; HouseHT/IterHT saturate by 14 threads.
//!
//! Writes `BENCH_fig9a.json` (override: `PARAHT_BENCH_OUT`) so the CI perf
//! job accumulates the scaling trajectory per commit — always *before* the
//! shape assertions run, so a hard-mode failure never discards the data.

use paraht::experiments::{common, figures};
use paraht::util::env;
use std::fmt::Write as _;

fn main() {
    let n: usize = env::bench_n(384);
    eprintln!("fig9a: random pencil n={n} (set PALLAS_BENCH_N to change)");
    let series = figures::fig9a(n, 42);

    let header: Vec<String> = common::PAPER_THREADS.iter().map(|p| format!("P={p}")).collect();
    let rows: Vec<(String, Vec<f64>)> = series
        .iter()
        .map(|s| (s.name.to_string(), s.points.iter().map(|&(_, v)| v).collect()))
        .collect();
    common::print_table(
        &format!("Fig 9a — speedup over sequential LAPACK, random pencil n={n}"),
        &header,
        &rows,
    );

    // Shape conditions (the paper's qualitative claims), evaluated up
    // front; asserted only after the JSON artifact is written.
    let tol = common::bench_tol();
    let para = &series[0];
    let p1 = para.points.first().unwrap().1;
    let plast = para.points.last().unwrap().1;
    // The paper's 1-core ParaHT trails LAPACK by the 21.33/14 flop ratio;
    // our WY kernels are per-flop faster than the rotation kernels, so at
    // larger n the ratio can approach (or pass) 1 — warn, don't fail.
    if p1 >= 1.0 {
        println!("note: 1-core ParaHT at {p1:.2}x LAPACK (per-flop kernel advantage offsets the extra flops at this n)");
    }
    let cond_plausible = p1 < 1.6 * tol;
    let cond_scales = plast > p1 * 1.5 / tol;

    // Kernel-speed-normalized one-core comparison (ROADMAP fig9a item):
    // dividing out the measured per-flop throughputs reduces the wall
    // ratio to the pure algorithmic flop ratio, which is deterministic —
    // the paper predicts ~21.33/14 at the §4 tuning (~24/14 scaled).
    let norm = figures::fig9a_one_core_normalized(n, 42);
    println!(
        "one-core normalized: flop ratio {:.3} (wall {:.3}; ParaHT {:.2} GFLOP/s, LAPACK {:.2} GFLOP/s)",
        norm.flop_ratio, norm.wall_ratio, norm.paraht_gflops, norm.lapack_gflops
    );
    let cond_norm = norm.flop_ratio > 1.15 && norm.flop_ratio < 2.8;
    // The band is calibrated for n >= 128 only; below that it neither
    // gates checks_held nor is asserted.
    let cond_norm_applies = n >= 128;
    let cond_norm_held = !cond_norm_applies || cond_norm;

    // ---- Emit BENCH_fig9a.json. ----
    let mut body = String::new();
    let _ = writeln!(body, "  \"n\": {n},");
    let _ = writeln!(
        body,
        "  \"one_core\": {{\"flop_ratio\": {}, \"wall_ratio\": {}, \"paraht_gflops\": {}, \"lapack_gflops\": {}}},",
        common::json_num(norm.flop_ratio),
        common::json_num(norm.wall_ratio),
        common::json_num(norm.paraht_gflops),
        common::json_num(norm.lapack_gflops)
    );
    body.push_str("  \"series\": [\n");
    for (i, s) in series.iter().enumerate() {
        let _ = write!(body, "    {{\"name\": \"{}\", \"points\": [", s.name);
        for (j, &(p, v)) in s.points.iter().enumerate() {
            let _ = write!(body, "{}[{p}, {}]", if j > 0 { ", " } else { "" }, common::json_num(v));
        }
        body.push_str(if i + 1 < series.len() { "]},\n" } else { "]}\n" });
    }
    body.push_str("  ],\n");
    let _ = write!(
        body,
        "  \"checks_held\": {}",
        cond_plausible && cond_scales && cond_norm_held
    );
    common::write_bench_json("BENCH_fig9a.json", "fig9a_threads", &body);

    let mut ok =
        common::bench_check(cond_plausible, &format!("1-core ParaHT implausibly fast: {p1:.2}"));
    ok &= common::bench_check(
        cond_scales,
        &format!("ParaHT must scale with P: {p1:.2} -> {plast:.2}"),
    );
    // Structural, not timing: flop counts are deterministic (this bench
    // runs the measured reductions single-threaded), so like table_flops
    // this stays a hard assert even in soft mode — but only at sizes the
    // (1.15, 2.8) band is calibrated for; at tiny PALLAS_BENCH_N the
    // lower-order terms dominate and the band is meaningless, so a
    // record-only run must not abort on it.
    if cond_norm_applies {
        assert!(
            cond_norm,
            "flop-normalized one-core ratio outside (1.15, 2.8): {:.3}",
            norm.flop_ratio
        );
    } else if !cond_norm {
        println!(
            "note: flop ratio {:.3} outside the n>=128 calibration band (n={n}; not asserted)",
            norm.flop_ratio
        );
    }
    if ok {
        if cond_norm_applies {
            println!("\nshape checks OK (ParaHT scales with P; comparators saturate; flop-normalized one-core ratio plausible)");
        } else {
            println!("\nshape checks OK (ParaHT scales with P; comparators saturate)");
        }
    }
}
