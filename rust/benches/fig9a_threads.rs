//! Bench: regenerate Fig. 9a — parallel speedup (fraction of the
//! single-threaded LAPACK runtime) for a random pencil, as a function of
//! the number of threads.
//!
//! Paper setup: n = 8000 on a 28-core Xeon. Here: a scaled n on measured
//! single-core task costs + the makespan simulator (DESIGN.md §5); the
//! reported quantity is the same *relative* speedup, so the curve shapes
//! are comparable: ParaHT starts below 1 (extra flops) and overtakes the
//! comparators as P grows; HouseHT/IterHT saturate by 14 threads.
//!
//! Writes `BENCH_fig9a.json` (override: `PARAHT_BENCH_OUT`) so the CI perf
//! job accumulates the scaling trajectory per commit — always *before* the
//! shape assertions run, so a hard-mode failure never discards the data.

use paraht::experiments::{common, figures};
use std::fmt::Write as _;

fn main() {
    let n: usize = std::env::var("PARAHT_BENCH_N")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(384);
    eprintln!("fig9a: random pencil n={n} (set PARAHT_BENCH_N to change)");
    let series = figures::fig9a(n, 42);

    let header: Vec<String> = common::PAPER_THREADS.iter().map(|p| format!("P={p}")).collect();
    let rows: Vec<(String, Vec<f64>)> = series
        .iter()
        .map(|s| (s.name.to_string(), s.points.iter().map(|&(_, v)| v).collect()))
        .collect();
    common::print_table(
        &format!("Fig 9a — speedup over sequential LAPACK, random pencil n={n}"),
        &header,
        &rows,
    );

    // Shape conditions (the paper's qualitative claims), evaluated up
    // front; asserted only after the JSON artifact is written.
    let tol = common::bench_tol();
    let para = &series[0];
    let p1 = para.points.first().unwrap().1;
    let plast = para.points.last().unwrap().1;
    // The paper's 1-core ParaHT trails LAPACK by the 21.33/14 flop ratio;
    // our WY kernels are per-flop faster than the rotation kernels, so at
    // larger n the ratio can approach (or pass) 1 — warn, don't fail.
    if p1 >= 1.0 {
        println!("note: 1-core ParaHT at {p1:.2}x LAPACK (per-flop kernel advantage offsets the extra flops at this n)");
    }
    let cond_plausible = p1 < 1.6 * tol;
    let cond_scales = plast > p1 * 1.5 / tol;

    // ---- Emit BENCH_fig9a.json. ----
    let mut body = String::new();
    let _ = writeln!(body, "  \"n\": {n},");
    body.push_str("  \"series\": [\n");
    for (i, s) in series.iter().enumerate() {
        let _ = write!(body, "    {{\"name\": \"{}\", \"points\": [", s.name);
        for (j, &(p, v)) in s.points.iter().enumerate() {
            let _ = write!(body, "{}[{p}, {}]", if j > 0 { ", " } else { "" }, common::json_num(v));
        }
        body.push_str(if i + 1 < series.len() { "]},\n" } else { "]}\n" });
    }
    body.push_str("  ],\n");
    let _ = write!(body, "  \"checks_held\": {}", cond_plausible && cond_scales);
    common::write_bench_json("BENCH_fig9a.json", "fig9a_threads", &body);

    let mut ok =
        common::bench_check(cond_plausible, &format!("1-core ParaHT implausibly fast: {p1:.2}"));
    ok &= common::bench_check(
        cond_scales,
        &format!("ParaHT must scale with P: {p1:.2} -> {plast:.2}"),
    );
    if ok {
        println!("\nshape checks OK (ParaHT scales with P; comparators saturate)");
    }
}
