//! Bench: tuned-vs-default end-to-end serving throughput.
//!
//! Runs the autotuner (budgeted trace-replay search, see `paraht::tune`),
//! then floods the serving tier twice with the same mixed-size pencil
//! stream — once on the untuned defaults, once with the tuned profile
//! installed — and reports pencils/sec for both.
//!
//! Correctness is hard-asserted up front: every flood size is reduced
//! through a profiled router and compared bitwise against
//! `api::reduce_seq` under the profile's effective config (overlay then
//! clip) — tuned profiles may change geometry, never results. Throughput
//! is timing-sensitive: the `tuned_no_slower_held` bar is evaluated
//! against the simulator's *prediction discipline* (tuned prediction ≤
//! default prediction holds structurally; measured wall-clock gets the
//! usual soft-mode/tolerance treatment), and the JSON artifact is written
//! *before* the assertion so a hard-mode failure never discards the data.
//!
//! Writes `BENCH_autotune.json` (override: `PALLAS_BENCH_OUT`) through
//! `common::write_bench_json`, sharing the NaN→null envelope with every
//! other bench artifact.
//!
//! Env knobs (canonical `PALLAS_` names; legacy `PARAHT_` aliases):
//! * `PALLAS_TUNE_SIZES=24,40` — representative sizes (one class each).
//! * `PALLAS_TUNE_BUDGET=6` — traced candidates per class.
//! * `PALLAS_SERVE_JOBS=120` — flood length per series.
//! * `PALLAS_BENCH_SOFT` / `PALLAS_BENCH_TOL` — soften / relax the
//!   tuned-no-slower assertion.

use paraht::api::reduce_seq;
use paraht::config::Config;
use paraht::experiments::common;
use paraht::pencil::random::random_pencil;
use paraht::pencil::Pencil;
use paraht::serve::{ServeConfig, ShardRouter, SubmitQueue};
use paraht::tune::{Autotuner, TuneOptions, TunedProfile};
use paraht::util::env;
use paraht::util::proptest::max_abs_diff;
use paraht::util::rng::Rng;
use std::fmt::Write as _;
use std::time::Instant;

/// Small-pencil serving base (band must fit the smallest flood size).
fn base_cfg() -> Config {
    Config { r: 8, p: 4, q: 4, ..Config::default() }
}

fn serve_cfg(profile: Option<TunedProfile>) -> ServeConfig {
    ServeConfig {
        shards: 2,
        threads_per_shard: 1,
        cache_entries: 0, // all-distinct flood: isolate reduction speed
        base: base_cfg(),
        profile,
        ..ServeConfig::default()
    }
}

fn flood(queue: &SubmitQueue, pool: &[Pencil], jobs: usize) -> f64 {
    let handle = queue.handle();
    let t = Instant::now();
    let tickets: Vec<_> = (0..jobs)
        .map(|i| {
            let p = &pool[i % pool.len()];
            handle.submit(p.a.clone(), p.b.clone()).expect("flood submission accepted")
        })
        .collect();
    for ticket in tickets {
        ticket.wait().expect("served reduction succeeds");
    }
    t.elapsed().as_secs_f64()
}

fn main() {
    let tune_sizes = env::tune_sizes(&[24, 40]);
    let budget = env::tune_budget(6);
    let jobs = env::serve_jobs(120).max(8);
    eprintln!(
        "autotune: classes at {tune_sizes:?}, budget {budget}, {jobs} flood jobs \
         (set PALLAS_TUNE_SIZES / PALLAS_TUNE_BUDGET / PALLAS_SERVE_JOBS to change)"
    );

    // ---- Search. ----
    let opts = TuneOptions { sizes: tune_sizes.clone(), threads: 2, budget, seed: 0x7_0BE };
    let tuner = Autotuner::new(base_cfg(), opts).expect("tuner inputs validate");
    let t_search = Instant::now();
    let (profile, reports) = tuner.run().expect("search completes");
    let search_secs = t_search.elapsed().as_secs_f64();
    for (c, rep) in profile.classes.iter().zip(&reports) {
        eprintln!(
            "class n>={}: r={} p={} q={} slices={} threads={} \
             predicted {:.6}s vs default {:.6}s ({} candidates)",
            c.n_min, c.r, c.p, c.q, c.slices, c.threads, c.predicted_makespan,
            rep.default_predicted, rep.candidates
        );
    }

    // The structural half of "tuned no slower": the simulator-predicted
    // makespan of every chosen config is ≤ the default's prediction on
    // the same trace. Hard — the argmin construction guarantees it.
    for (c, rep) in profile.classes.iter().zip(&reports) {
        assert!(
            c.predicted_makespan <= rep.default_predicted,
            "class n>={}: chosen prediction {} exceeds default {}",
            c.n_min,
            c.predicted_makespan,
            rep.default_predicted
        );
    }

    // ---- Hard bitwise gate: a profiled router serves every flood size
    // exactly like the sequential oracle under the tuned effective
    // config (profile overlay, then the serving band clip). ----
    let flood_sizes: Vec<usize> = {
        // The tuned classes' representative sizes plus edge sizes: a
        // pencil below every class floor, one below the base band (clip
        // path), and the n = 2 no-op.
        let mut v = vec![2usize, 6, 13];
        v.extend(tune_sizes.iter().copied());
        v
    };
    let mut rng = Rng::new(0xA_07_0E);
    let gate_router = ShardRouter::new(serve_cfg(Some(profile.clone()))).unwrap();
    for &n in &flood_sizes {
        let p = random_pencil(n, &mut rng);
        let d = gate_router.reduce(&p.a, &p.b).unwrap();
        let eff = profile.apply(&base_cfg(), n).clipped_for(n);
        let oracle = reduce_seq(&p.a, &p.b, &eff).unwrap();
        assert_eq!(max_abs_diff(&d.h, &oracle.h), 0.0, "n={n}: tuned H diverges");
        assert_eq!(max_abs_diff(&d.t, &oracle.t), 0.0, "n={n}: tuned T diverges");
        assert_eq!(max_abs_diff(&d.q, &oracle.q), 0.0, "n={n}: tuned Q diverges");
        assert_eq!(max_abs_diff(&d.z, &oracle.z), 0.0, "n={n}: tuned Z diverges");
    }
    drop(gate_router);

    // ---- Tuned-vs-default flood series. ----
    let pool: Vec<Pencil> = (0..jobs.min(48))
        .map(|i| random_pencil(flood_sizes[i % flood_sizes.len()], &mut rng))
        .collect();
    let mut series: Vec<(&str, f64, f64)> = Vec::new();
    for (label, prof) in [("default", None), ("tuned", Some(profile.clone()))] {
        let queue = SubmitQueue::new(ShardRouter::new(serve_cfg(prof)).unwrap());
        flood(&queue, &pool, jobs.min(24)); // warmup
        let secs = flood(&queue, &pool, jobs);
        queue.shutdown();
        let pps = jobs as f64 / secs;
        println!("{label:<10}{jobs:>8} jobs{secs:>12.4}s{pps:>14.1} pencils/sec");
        series.push((label, secs, pps));
    }
    let pps_default = series[0].2;
    let pps_tuned = series[1].2;
    let speedup = pps_tuned / pps_default;
    // Timing-sensitive half of the bar: measured tuned throughput must
    // not trail the default beyond the tolerance. (Predictions already
    // hold structurally above.)
    let tuned_no_slower_held = speedup >= 1.0 / common::bench_tol();

    // ---- Emit BENCH_autotune.json (before any soft/hard assertion). ----
    let mut body = String::new();
    let _ = writeln!(body, "  \"jobs\": {jobs},");
    let _ = writeln!(body, "  \"tune_sizes\": {tune_sizes:?},");
    let _ = writeln!(body, "  \"budget\": {budget},");
    let _ = writeln!(body, "  \"search_secs\": {:.6},", search_secs);
    body.push_str("  \"classes\": [\n");
    for (i, (c, rep)) in profile.classes.iter().zip(&reports).enumerate() {
        let _ = write!(
            body,
            "    {{\"n_min\": {}, \"n_max\": {}, \"r\": {}, \"p\": {}, \"q\": {}, \
             \"slices\": {}, \"threads\": {}, \"predicted_makespan\": {}, \
             \"default_makespan\": {}, \"candidates\": {}}}",
            c.n_min,
            c.n_max,
            c.r,
            c.p,
            c.q,
            c.slices,
            c.threads,
            common::json_num(c.predicted_makespan),
            common::json_num(rep.default_predicted),
            rep.candidates
        );
        body.push_str(if i + 1 < profile.classes.len() { ",\n" } else { "\n" });
    }
    body.push_str("  ],\n");
    body.push_str("  \"series\": [\n");
    for (i, (label, secs, pps)) in series.iter().enumerate() {
        let _ = write!(
            body,
            "    {{\"config\": \"{label}\", \"jobs\": {jobs}, \"secs\": {:.6}, \
             \"pencils_per_sec\": {}}}",
            secs,
            common::json_num(*pps)
        );
        body.push_str(if i + 1 < series.len() { ",\n" } else { "\n" });
    }
    body.push_str("  ],\n");
    let _ = writeln!(body, "  \"speedup_tuned\": {},", common::json_num(speedup));
    let _ = write!(body, "  \"tuned_no_slower_held\": {tuned_no_slower_held}");
    common::write_bench_json("BENCH_autotune.json", "autotune", &body);

    if common::bench_check(
        tuned_no_slower_held,
        &format!(
            "tuned serving must not trail the default: {pps_tuned:.1} vs {pps_default:.1} \
             pencils/sec (speedup {speedup:.3}x)"
        ),
    ) {
        println!(
            "\nshape checks OK (tuned parity exact; predictions ≤ default; tuned no slower)"
        );
    }
}
