//! End-to-end validation driver (EXPERIMENTS.md §End-to-end): run the full
//! ParaHT system — coordinator, task DAG, dynamic scheduler — on a real
//! small workload, report the paper's headline metric (parallel speedup
//! over sequential LAPACK) and the backward error.
//!
//! ```text
//! cargo run --release --example scaling [n]
//! ```

use paraht::api::HtSession;
use paraht::coordinator::driver::{lapack_seq_time, paraht_curve};
use paraht::coordinator::graph::TaskClass;
use paraht::coordinator::sim::Simulator;
use paraht::experiments::common::{scaled_config, PAPER_THREADS};
use paraht::pencil::random::random_pencil;
use paraht::util::rng::Rng;

fn main() {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(512);
    let mut rng = Rng::new(7777);
    let pencil = random_pencil(n, &mut rng);
    let cfg = scaled_config(n);
    println!(
        "ParaHT scaling study, random pencil n={n} (r={}, p={}, q={})",
        cfg.r, cfg.p, cfg.q
    );

    // Reference: sequential LAPACK-style (Moler–Stewart) runtime.
    let t_lapack = lapack_seq_time(&pencil.a, &pencil.b);
    println!("sequential LAPACK (Moler–Stewart): {t_lapack:.3}s");

    // ParaHT through a trace-capturing session: real execution + task
    // trace for simulation.
    let mut session =
        HtSession::builder().config(cfg).capture_traces(true).build().unwrap();
    let run = session.reduce(&pencil.a, &pencil.b).unwrap();
    let v = run.verify(&pencil.a, &pencil.b);
    println!(
        "ParaHT backward error: A {:.2e}, B {:.2e} (machine precision)",
        v.err_a, v.err_b
    );
    assert!(v.worst() < 1e-10);

    let traces = session.take_traces().unwrap();
    println!(
        "task graph: stage1 {} tasks, stage2 {} tasks ({} lookahead)",
        traces.0.durations.len(),
        traces.1.durations.len(),
        traces.1.classes.iter().filter(|c| **c == TaskClass::Look2).count()
    );
    println!(
        "ParaHT 1-core total: {:.3}s",
        traces.0.total().as_secs_f64() + traces.1.total().as_secs_f64()
    );

    let curve = paraht_curve(&traces, PAPER_THREADS);
    println!(
        "\n{:<6}{:>12}{:>14}{:>16}{:>14}",
        "P", "makespan", "self-speedup", "vs LAPACK(seq)", "utilization"
    );
    // Memoized simulators: the whole P sweep costs max(P) greedy replays.
    let mut sim1 = Simulator::new(&traces.0);
    let mut sim2 = Simulator::new(&traces.1);
    for &(p, t) in &curve.points {
        let u1 = sim1.result(p);
        let u2 = sim2.result(p);
        let util = (u1.total_work + u2.total_work) / ((u1.makespan + u2.makespan) * p as f64);
        println!(
            "{p:<6}{t:>12.3}{:>14.2}{:>16.2}{util:>14.2}",
            curve.t1 / t,
            t_lapack / t
        );
    }
    println!(
        "\nheadline: at P=28 ParaHT reaches {:.2}x over sequential LAPACK \
         (paper Fig. 9a: ~4x at n=8000 on 28 cores)",
        t_lapack / curve.points.last().unwrap().1
    );
}
