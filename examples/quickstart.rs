//! Quickstart: open a reduction session, reduce a random pencil to
//! Hessenberg-triangular form, verify the decomposition — and reduce a
//! second pencil on the *same* session to show the setup being reused.
//!
//! ```text
//! cargo run --release --example quickstart [n]
//! ```

use paraht::api::HtSession;
use paraht::pencil::random::random_pencil;
use paraht::util::rng::Rng;

fn main() {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(256);
    println!("quickstart: Hessenberg-triangular reduction of a random {n}x{n} pencil");

    // 1. A random pencil (B pre-triangularized, as in the paper's §4).
    let mut rng = Rng::new(1234);
    let pencil = random_pencil(n, &mut rng);

    // 2. A session with the paper's tuning (r=16, p=8, q=8): the config is
    //    validated once, the worker team resolved once, and the per-size
    //    workspaces built on first use.
    let mut session = HtSession::builder().threads(4).build().expect("valid config");
    let d = session.reduce(&pencil.a, &pencil.b).expect("reduction succeeds");
    let r = session.config().r;
    println!("stage 1 (to {r}-Hessenberg-triangular): {:.3}s", d.stage1_secs);
    println!("stage 2 (bulge chasing to HT form):    {:.3}s", d.stage2_secs);

    // 3. Verify: A = Q H Zᵀ, B = Q T Zᵀ to machine precision.
    let v = d.verify(&pencil.a, &pencil.b);
    println!(
        "backward errors: A {:.2e}, B {:.2e}; orthogonality: Q {:.2e}, Z {:.2e}",
        v.err_a, v.err_b, v.orth_q, v.orth_z
    );
    assert!(v.worst() < 1e-11, "verification failed");

    // 4. A second pencil through the same session: workspaces (panel
    //    plans, sweep groups, reflector arenas) and the warm worker pool
    //    are reused — only the numerical work is paid again.
    let pencil2 = random_pencil(n, &mut rng);
    let d2 = session.reduce(&pencil2.a, &pencil2.b).expect("second reduction");
    assert!(d2.verify(&pencil2.a, &pencil2.b).worst() < 1e-11);
    println!(
        "second reduction on the warm session: stage 1 {:.3}s, stage 2 {:.3}s",
        d2.stage1_secs, d2.stage2_secs
    );
    println!("OK — H is Hessenberg, T is triangular, factors orthogonal.");
}
