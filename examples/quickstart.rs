//! Quickstart: reduce a random pencil to Hessenberg-triangular form and
//! verify the decomposition.
//!
//! ```text
//! cargo run --release --example quickstart [n]
//! ```

use paraht::config::Config;
use paraht::ht::reduce_to_hessenberg_triangular;
use paraht::pencil::random::random_pencil;
use paraht::util::rng::Rng;

fn main() {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(256);
    println!("quickstart: Hessenberg-triangular reduction of a random {n}x{n} pencil");

    // 1. A random pencil (B pre-triangularized, as in the paper's §4).
    let mut rng = Rng::new(1234);
    let pencil = random_pencil(n, &mut rng);

    // 2. Reduce with the paper's tuning (r=16, p=8, q=8).
    let cfg = Config::default();
    let d = reduce_to_hessenberg_triangular(&pencil.a, &pencil.b, &cfg)
        .expect("reduction succeeds");
    println!("stage 1 (to {}-Hessenberg-triangular): {:.3}s", cfg.r, d.stage1_secs);
    println!("stage 2 (bulge chasing to HT form):    {:.3}s", d.stage2_secs);

    // 3. Verify: A = Q H Zᵀ, B = Q T Zᵀ to machine precision.
    let v = d.verify(&pencil.a, &pencil.b);
    println!(
        "backward errors: A {:.2e}, B {:.2e}; orthogonality: Q {:.2e}, Z {:.2e}",
        v.err_a, v.err_b, v.orth_q, v.orth_z
    );
    assert!(v.worst() < 1e-11, "verification failed");
    println!("OK — H is Hessenberg, T is triangular, factors orthogonal.");
}
