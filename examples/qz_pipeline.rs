//! End-to-end generalized eigenvalue pipeline: the HT reduction as the QZ
//! preprocessing step it exists for (§1 of the paper).
//!
//! Builds a pencil with a *known* real spectrum, reduces it with the
//! two-stage algorithm, runs the single-shift QZ iteration on the
//! Hessenberg-triangular result, and checks the recovered eigenvalues.
//!
//! ```text
//! cargo run --release --example qz_pipeline [n]
//! ```

use paraht::api::HtSession;
use paraht::ht::qz::{pencil_with_spectrum, qz};
use paraht::util::rng::Rng;
use paraht::util::timer::Timer;

fn main() {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(64);
    let mut rng = Rng::new(2024);

    // Known spectrum: λ_i = i − n/2 (distinct, real, unit gaps — keeps the
    // eigenproblem well conditioned at larger n).
    let want: Vec<f64> = (0..n).map(|i| i as f64 - n as f64 / 2.0).collect();
    let (a, b) = pencil_with_spectrum(&want, &mut rng);
    println!(
        "pencil n={n} with prescribed real spectrum in [{:.2}, {:.2}]",
        want[0],
        want[n - 1]
    );

    // Phase 1+2: two-stage Hessenberg-triangular reduction through the
    // session front door.
    let mut session = HtSession::builder().band(8).block(4).group(4).build().unwrap();
    let t = Timer::start();
    let d = session.reduce(&a, &b).unwrap();
    println!(
        "HT reduction: {:.3}s (stage1 {:.3}s, stage2 {:.3}s)",
        t.secs(),
        d.stage1_secs,
        d.stage2_secs
    );
    d.verify(&a, &b).assert_ok(1e-10);

    // Phase 3: QZ iteration on the HT pencil.
    let (mut h, mut t2) = (d.h.clone(), d.t.clone());
    let (mut q, mut z) = (d.q.clone(), d.z.clone());
    let timer = Timer::start();
    let res = qz(&mut h, &mut t2, &mut q, &mut z, 50 * n).expect("QZ converges on real spectrum");
    println!("QZ iteration: {:.3}s, {} iterations", timer.secs(), res.iterations);

    // Compare recovered vs prescribed eigenvalues (all real by
    // construction; tolerate tiny imaginary parts from near-degenerate
    // pairs).
    let mut got: Vec<f64> = res.eigenvalues.iter().map(|&(re, _)| re).collect();
    let max_im = res.eigenvalues.iter().map(|&(_, im)| im.abs()).fold(0.0f64, f64::max);
    println!("largest imaginary part: {max_im:.2e}");
    got.sort_by(|x, y| x.partial_cmp(y).unwrap());
    let mut want_sorted = want.clone();
    want_sorted.sort_by(|x, y| x.partial_cmp(y).unwrap());
    let max_err = got
        .iter()
        .zip(&want_sorted)
        .map(|(g, w)| (g - w).abs() / w.abs().max(1.0))
        .fold(0.0f64, f64::max);
    println!("max relative eigenvalue error: {max_err:.2e}");
    assert!(max_err < 1e-5, "eigenvalues diverged");
    println!("OK — full pipeline (stage 1 → stage 2 → QZ) reproduces the spectrum.");
}
