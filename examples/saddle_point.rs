//! Saddle-point pencils (§4, Fig. 11): 25% of the spectrum at infinity.
//!
//! Demonstrates the paper's robustness claim: ParaHT and the LAPACK-style
//! rotation baselines are oblivious to infinite eigenvalues; HouseHT pays
//! per-block refinement; IterHT fails to converge.
//!
//! ```text
//! cargo run --release --example saddle_point [n]
//! ```

use paraht::api::HtSession;
use paraht::baselines::househt::{self, HouseHtOpts};
use paraht::baselines::iterht::{self, IterHtOpts};
use paraht::linalg::matrix::Matrix;
use paraht::pencil::saddle::saddle_pencil;
use paraht::util::rng::Rng;
use paraht::util::timer::Timer;

fn main() {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(192);
    let mut rng = Rng::new(99);
    let pencil = saddle_pencil(n, 0.25, &mut rng);
    println!(
        "saddle-point pencil n={n}, {} infinite eigenvalues ({}%)",
        pencil.infinite_eigenvalues,
        100 * pencil.infinite_eigenvalues / n
    );

    // ParaHT: unaffected by the singular B.
    let mut session = HtSession::builder().band(8).block(4).group(4).build().unwrap();
    let t = Timer::start();
    let d = session.reduce(&pencil.a, &pencil.b).unwrap();
    let v = d.verify(&pencil.a, &pencil.b);
    println!("ParaHT : {:.3}s  backward error {:.2e}  — OK", t.secs(), v.err_a.max(v.err_b));

    // HouseHT: succeeds, but pays refinement fallbacks on singular blocks.
    let (mut a, mut b) = (pencil.a.clone(), pencil.b.clone());
    let (mut q, mut z) = (Matrix::identity(n), Matrix::identity(n));
    let t = Timer::start();
    let stats = househt::reduce(&mut a, &mut b, &mut q, &mut z, &HouseHtOpts::default()).unwrap();
    println!(
        "HouseHT: {:.3}s  refinement fallbacks: {} / {} blocks — slower but correct",
        t.secs(),
        stats.fallbacks,
        stats.blocks
    );

    // IterHT: fails to converge, exactly as reported under Fig. 11.
    let (mut a, mut b) = (pencil.a.clone(), pencil.b.clone());
    let (mut q, mut z) = (Matrix::identity(n), Matrix::identity(n));
    match iterht::reduce(&mut a, &mut b, &mut q, &mut z, &IterHtOpts::default()) {
        Ok(_) => println!("IterHT : unexpectedly converged"),
        Err(e) => println!("IterHT : {e}"),
    }
}
