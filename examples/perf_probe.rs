//! Perf probe: throughput of the hot kernels (EXPERIMENTS.md §Perf).
use paraht::linalg::gemm::{gemm, Trans};
use paraht::linalg::matrix::Matrix;
use paraht::linalg::qr::QrFactor;
use paraht::linalg::wy::{Side, WyRep};
use paraht::util::rng::Rng;
use paraht::util::timer::bench_min;

fn main() {
    let mut rng = Rng::new(1);
    println!("{:<34}{:>10}", "kernel", "GFlop/s");
    // Square GEMM
    for n in [128usize, 256, 512] {
        let a = Matrix::randn(n, n, &mut rng);
        let b = Matrix::randn(n, n, &mut rng);
        let mut c = Matrix::zeros(n, n);
        let t = bench_min(3, 0.2, || {
            gemm(1.0, a.as_ref(), Trans::No, b.as_ref(), Trans::No, 0.0, c.as_mut())
        });
        println!("{:<34}{:>10.2}", format!("gemm nn {n}x{n}x{n}"), 2.0 * (n as f64).powi(3) / t / 1e9);
    }
    // Thin GEMMs of the WY apply (m x k with k=16)
    for (m, k, nc) in [(128usize, 16usize, 512usize), (256, 16, 512)] {
        let v = Matrix::randn(m, k, &mut rng);
        let c = Matrix::randn(m, nc, &mut rng);
        let mut x = Matrix::zeros(k, nc);
        let t = bench_min(3, 0.2, || {
            gemm(1.0, v.as_ref(), Trans::Yes, c.as_ref(), Trans::No, 0.0, x.as_mut())
        });
        println!("{:<34}{:>10.2}", format!("gemm tn {k}x{nc}x{m}"), 2.0 * (m * k * nc) as f64 / t / 1e9);
        let mut c2 = c.clone();
        let t = bench_min(3, 0.2, || {
            gemm(-1.0, v.as_ref(), Trans::No, x.as_ref(), Trans::No, 1.0, c2.as_mut())
        });
        println!("{:<34}{:>10.2}", format!("gemm nn {m}x{nc}x{k}"), 2.0 * (m * k * nc) as f64 / t / 1e9);
    }
    // Full WY apply (the stage-1 L_A unit)
    for (m, k, nc) in [(128usize, 16usize, 512usize)] {
        let vm = Matrix::randn(m, k, &mut rng);
        let wy: WyRep = QrFactor::compute_inplace(vm).wy();
        let mut c = Matrix::randn(m, nc, &mut rng);
        let t = bench_min(3, 0.3, || {
            wy.apply(Side::Left, paraht::linalg::Trans::Yes, c.as_mut())
        });
        println!("{:<34}{:>10.2}", format!("wy apply left {m}x{nc} k={k}"), 4.0 * (m * k * nc) as f64 / t / 1e9);
    }
    // Rotation kernel reference (what moler_stewart runs at)
    {
        let n = 512;
        let mut m = Matrix::randn(n, n, &mut rng);
        let g = paraht::linalg::givens::Givens { c: 0.8, s: 0.6 };
        let t = bench_min(3, 0.2, || {
            for i in 0..n - 1 {
                g.apply_left(m.as_mut(), i, i + 1, 0..n);
            }
        });
        println!("{:<34}{:>10.2}", "givens row sweep 512", 6.0 * ((n - 1) * n) as f64 / t / 1e9);
    }
}
