"""L1 correctness: Pallas WY kernels vs the pure-jnp oracle.

This is the CORE correctness signal for the kernel layer. hypothesis
sweeps shapes; explicit cases pin the AOT bucket shapes and compare
against a dense `Q = I - V T V^T` construction.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

jax.config.update("jax_enable_x64", True)

from compile.kernels.ref import (  # noqa: E402
    form_q_ref,
    wy_apply_left_ref,
    wy_apply_right_ref,
)
from compile.kernels.wy_apply import (  # noqa: E402
    BLOCK_M,
    BLOCK_N,
    wy_apply_left,
    wy_apply_right,
)


def wy_factors(rng, m, k, dtype=np.float64):
    """Random unit-lower V and a valid larft-style T (upper triangular)."""
    v = np.tril(rng.standard_normal((m, k)), -1).astype(dtype)
    for i in range(k):
        v[i, i] = 1.0
    # tau = 2/||v||^2 makes each reflector (and hence Q) exactly orthogonal.
    taus = (2.0 / np.sum(v * v, axis=0)).astype(dtype)
    t = np.zeros((k, k), dtype=dtype)
    for i in range(k):
        t[i, i] = taus[i]
        if i > 0:
            w = v[:, :i].T @ v[:, i]
            t[:i, i] = -taus[i] * (t[:i, :i] @ w)
    return jnp.asarray(v), jnp.asarray(t)


@pytest.mark.parametrize("m,k,n", [(128, 16, 128), (128, 16, 256), (64, 8, 128)])
def test_left_matches_ref_bucket_shapes(m, k, n):
    rng = np.random.default_rng(1)
    v, t = wy_factors(rng, m, k)
    c = jnp.asarray(rng.standard_normal((m, n)))
    got = wy_apply_left(c, v, t)
    want = wy_apply_left_ref(c, v, t)
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)
    # Against dense Q^T C.
    q = form_q_ref(v, t)
    np.testing.assert_allclose(got, q.T @ c, rtol=1e-11, atol=1e-11)


@pytest.mark.parametrize("mr,m,k", [(128, 128, 16), (256, 128, 16), (128, 64, 8)])
def test_right_matches_ref_bucket_shapes(mr, m, k):
    rng = np.random.default_rng(2)
    v, t = wy_factors(rng, m, k)
    c = jnp.asarray(rng.standard_normal((mr, m)))
    got = wy_apply_right(c, v, t)
    want = wy_apply_right_ref(c, v, t)
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)
    q = form_q_ref(v, t)
    np.testing.assert_allclose(got, c @ q, rtol=1e-11, atol=1e-11)


def test_orthogonality_preserved():
    """Q from WY factors is orthogonal => applying preserves column norms."""
    rng = np.random.default_rng(3)
    v, t = wy_factors(rng, 128, 16)
    c = jnp.asarray(rng.standard_normal((128, 128)))
    out = wy_apply_left(c, v, t)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(out), axis=0),
        np.linalg.norm(np.asarray(c), axis=0),
        rtol=1e-10,
    )


@settings(max_examples=15, deadline=None)
@given(
    m_blocks=st.integers(1, 2),
    k=st.integers(1, 16),
    n_blocks=st.integers(1, 2),
    seed=st.integers(0, 2**31 - 1),
)
def test_left_hypothesis_shapes(m_blocks, k, n_blocks, seed):
    m = 64 * m_blocks
    n = BLOCK_N * n_blocks
    k = min(k, m)
    rng = np.random.default_rng(seed)
    v, t = wy_factors(rng, m, k)
    c = jnp.asarray(rng.standard_normal((m, n)))
    np.testing.assert_allclose(
        wy_apply_left(c, v, t), wy_apply_left_ref(c, v, t), rtol=1e-11, atol=1e-11
    )


@settings(max_examples=15, deadline=None)
@given(
    mr_blocks=st.integers(1, 2),
    m=st.sampled_from([32, 64, 128]),
    k=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_right_hypothesis_shapes(mr_blocks, m, k, seed):
    mr = BLOCK_M * mr_blocks
    k = min(k, m)
    rng = np.random.default_rng(seed)
    v, t = wy_factors(rng, m, k)
    c = jnp.asarray(rng.standard_normal((mr, m)))
    np.testing.assert_allclose(
        wy_apply_right(c, v, t), wy_apply_right_ref(c, v, t), rtol=1e-11, atol=1e-11
    )


def test_float32_dtype():
    rng = np.random.default_rng(4)
    v, t = wy_factors(rng, 64, 8, dtype=np.float32)
    c = jnp.asarray(rng.standard_normal((64, 128)).astype(np.float32))
    got = wy_apply_left(c, v, t)
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(got, wy_apply_left_ref(c, v, t), rtol=1e-5, atol=1e-5)
