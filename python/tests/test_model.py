"""L2 tests: bucket model functions, shapes, and the AOT HLO-text path."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

from compile.aot import bucket_kind, to_hlo_text  # noqa: E402
from compile.kernels.ref import wy_apply_left_ref, wy_apply_right_ref  # noqa: E402
from compile.model import BUCKETS, apply_left, apply_right, bucket_args, panel_update  # noqa: E402


def wy_factors(rng, m, k):
    v = np.tril(rng.standard_normal((m, k)), -1)
    for i in range(k):
        v[i, i] = 1.0
    taus = 2.0 / np.sum(v * v, axis=0)
    t = np.zeros((k, k))
    for i in range(k):
        t[i, i] = taus[i]
        if i > 0:
            w = v[:, :i].T @ v[:, i]
            t[:i, i] = -taus[i] * (t[:i, :i] @ w)
    return jnp.asarray(v), jnp.asarray(t)


def test_every_bucket_lowers_to_hlo_text():
    for name, fn, shapes in BUCKETS:
        lowered = jax.jit(fn).lower(*bucket_args(shapes))
        text = to_hlo_text(lowered)
        assert "ENTRY" in text, f"{name}: no ENTRY in HLO"
        assert "f64" in text, f"{name}: expected f64 module"
        assert len(text) > 1000


def test_bucket_kinds():
    kinds = {bucket_kind(name) for name, _, _ in BUCKETS}
    assert kinds == {"left", "right", "panel"}


def test_bucket_shapes_consistent():
    for name, _, shapes in BUCKETS:
        cm, cn = shapes[0]
        vk = shapes[1]
        assert vk[1] == shapes[2][0] == shapes[2][1], f"{name}: T must be k×k"
        if bucket_kind(name) == "left":
            assert vk[0] == cm, f"{name}: V rows must match C rows"
        elif bucket_kind(name) == "right":
            assert vk[0] == cn, f"{name}: V rows must match C cols"


def test_panel_update_equals_composition():
    """panel_update = apply_left then apply_right, against the oracle."""
    rng = np.random.default_rng(7)
    m, k = 128, 16
    vq, tq = wy_factors(rng, m, k)
    vz, tz = wy_factors(rng, m, k)
    c = jnp.asarray(rng.standard_normal((m, m)))
    (got,) = panel_update(c, vq, tq, vz, tz)
    want = wy_apply_right_ref(wy_apply_left_ref(c, vq, tq), vz, tz)
    np.testing.assert_allclose(got, want, rtol=1e-11, atol=1e-11)


def test_apply_wrappers_return_tuples():
    rng = np.random.default_rng(8)
    v, t = wy_factors(rng, 128, 16)
    c = jnp.asarray(rng.standard_normal((128, 128)))
    out = apply_left(c, v, t)
    assert isinstance(out, tuple) and len(out) == 1
    out = apply_right(c, v, t)
    assert isinstance(out, tuple) and len(out) == 1


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "manifest.txt")),
    reason="artifacts not built",
)
def test_manifest_matches_buckets():
    path = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "manifest.txt")
    with open(path) as f:
        lines = [l.split() for l in f if l.strip()]
    names = {l[0] for l in lines}
    assert names == {name for name, _, _ in BUCKETS}
    for l in lines:
        assert len(l) == 6
        hlo = os.path.join(os.path.dirname(path), l[5])
        assert os.path.exists(hlo), f"missing artifact {hlo}"
