"""AOT: lower the L2 bucket functions to HLO *text* artifacts.

HLO text — NOT serialized HloModuleProto: jax >= 0.5 emits protos with
64-bit instruction ids which the xla crate's xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Outputs:
  artifacts/<name>.hlo.txt       one module per bucket
  artifacts/manifest.txt         one line per artifact:
      name kind m n k path
  (kind = left | right | panel; m,n,k = bucket dims of C and V)

Python runs ONCE at build time (`make artifacts`); the rust binary only
reads the artifacts.
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

jax.config.update("jax_enable_x64", True)

from .model import BUCKETS, bucket_args  # noqa: E402


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def bucket_kind(name: str) -> str:
    if name.startswith("wy_left"):
        return "left"
    if name.startswith("wy_right"):
        return "right"
    return "panel"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest_lines = []
    for name, fn, shapes in BUCKETS:
        lowered = jax.jit(fn).lower(*bucket_args(shapes))
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        cm, cn = shapes[0]
        k = shapes[1][1]
        manifest_lines.append(
            f"{name} {bucket_kind(name)} {cm} {cn} {k} {name}.hlo.txt"
        )
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote manifest with {len(manifest_lines)} artifacts")


if __name__ == "__main__":
    main()
