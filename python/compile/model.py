"""L2 — the JAX compute graphs that get AOT-lowered to HLO artifacts.

Each exported function wraps the L1 Pallas kernels into the exact update
the rust coordinator offloads:

* ``apply_left``  — `C ← QᵀC`  (stage-1 `L_A`/`L_B`-style left updates)
* ``apply_right`` — `C ← C·Q`  (stage-1 `R_A`/`R_Z`, stage-2 WY sweeps)
* ``panel_update`` — the fused stage-1 panel step: the left update of a
  trailing block followed by a right opposite-reflector update, one HLO
  module so XLA can schedule both GEMM pairs together.

The functions are shape-monomorphic: `aot.py` lowers one HLO module per
bucket shape listed in `BUCKETS`, and the rust runtime pads panels to the
nearest bucket (`runtime/bucket.rs`).
"""

import jax.numpy as jnp

from .kernels.wy_apply import wy_apply_left, wy_apply_right


def apply_left(c, v, t):
    """C ← (I − V T Vᵀ)ᵀ C via the fused Pallas kernel."""
    return (wy_apply_left(c, v, t),)


def apply_right(c, v, t):
    """C ← C (I − V T Vᵀ) via the fused Pallas kernel."""
    return (wy_apply_right(c, v, t),)


def panel_update(c, vq, tq, vz, tz):
    """Fused stage-1 block step on a square trailing tile:
    left `Q̂ᵀ` then right `Ẑ` — both WY applications in one module."""
    c1 = wy_apply_left(c, vq, tq)
    c2 = wy_apply_right(c1, vz, tz)
    return (c2,)


# (name, function, [shapes of parameters]) — f64 everywhere to match the
# rust substrate. m = p·r = 128, k = r = 16 are the paper's tunings.
BUCKETS = [
    ("wy_left_128x16_n128", apply_left, [(128, 128), (128, 16), (16, 16)]),
    ("wy_left_128x16_n256", apply_left, [(128, 256), (128, 16), (16, 16)]),
    ("wy_right_128x16_m128", apply_right, [(128, 128), (128, 16), (16, 16)]),
    ("wy_right_128x16_m256", apply_right, [(256, 128), (128, 16), (16, 16)]),
    (
        "panel_update_128",
        panel_update,
        [(128, 128), (128, 16), (16, 16), (128, 16), (16, 16)],
    ),
]


def bucket_args(shapes, dtype=jnp.float64):
    """ShapeDtypeStructs for lowering."""
    import jax

    return [jax.ShapeDtypeStruct(s, dtype) for s in shapes]
