"""Pure-jnp oracle for the WY block-reflector kernels.

The compact-WY representation is ``Q = I - V T V^T`` (LAPACK larfb,
forward/columnwise). These references define the exact semantics the
Pallas kernels (and the rust ``linalg::wy`` implementation) must match:

* left  (trans): ``C <- Q^T C = C - V (T^T (V^T C))``
* right (no-trans): ``C <- C Q = C - ((C V) T) V^T``

which are the two hot-path applications of the paper's stage-1/stage-2
updates (L_A, L_B, R_A, R_Z and the stage-2 WY sweeps).
"""

import jax.numpy as jnp


def wy_apply_left_ref(c, v, t):
    """C <- (I - V T V^T)^T C = C - V T^T V^T C."""
    w = v.T @ c          # (k, nc)
    x = t.T @ w          # (k, nc)
    return c - v @ x


def wy_apply_right_ref(c, v, t):
    """C <- C (I - V T V^T) = C - C V T V^T."""
    w = c @ v            # (mc, k)
    x = w @ t            # (mc, k)
    return c - x @ v.T


def form_q_ref(v, t):
    """Materialize Q = I - V T V^T (m x m)."""
    m = v.shape[0]
    return jnp.eye(m, dtype=v.dtype) - v @ t @ v.T
