"""L1 — Pallas WY block-reflector kernels.

The paper's hot spot is the application of compact-WY block reflectors
(`Q = I − V T Vᵀ`) to large matrix panels — two thin GEMMs per panel.

TPU adaptation (DESIGN.md §Hardware-Adaptation): on the paper's Xeon the
update is cache-blocked; here it is re-thought for the MXU/VMEM model:

* `C` is tiled along its long dimension by the Pallas grid; each grid step
  holds one `(m × BN)` (left) or `(BM × m)` (right) tile of `C` in VMEM.
* `V` (`m × k`, `k = r = 16`) and `T` (`k × k`) are small and replicated
  into VMEM for every grid step (their BlockSpec index map is constant).
* Both GEMMs of the update are **fused in one kernel**, so the `k`-thin
  intermediate (`Vᵀ C` / `C V`) never round-trips through HBM.

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; numerics are validated on the interpret path (pytest +
hypothesis vs `ref.py`), and the real-TPU resource estimate lives in
DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile widths for the long dimension of C. 128 matches the MXU/VREG lane
# width; the bucketed runtime pads the ragged remainder.
BLOCK_N = 128
BLOCK_M = 128


def _wy_left_kernel(v_ref, t_ref, c_ref, o_ref):
    """One C-tile of ``C - V (T^T (V^T C))``; all operands VMEM-resident."""
    v = v_ref[...]                    # (m, k)
    t = t_ref[...]                    # (k, k)
    c = c_ref[...]                    # (m, bn)
    w = v.T @ c                       # (k, bn)   thin GEMM 1
    x = t.T @ w                       # (k, bn)   tiny triangular GEMM
    o_ref[...] = c - v @ x            # (m, bn)   thin GEMM 2 (fused)


def _wy_right_kernel(v_ref, t_ref, c_ref, o_ref):
    """One C-tile of ``C - ((C V) T) V^T``."""
    v = v_ref[...]                    # (m, k)
    t = t_ref[...]                    # (k, k)
    c = c_ref[...]                    # (bm, m)
    w = c @ v                         # (bm, k)
    x = w @ t                         # (bm, k)
    o_ref[...] = c - x @ v.T          # (bm, m)


@functools.partial(jax.jit, static_argnames=())
def wy_apply_left(c, v, t):
    """``C ← QᵀC`` for ``Q = I − V T Vᵀ``; C is (m, n) with n a multiple of
    BLOCK_N (the AOT buckets guarantee this; the runtime pads)."""
    m, n = c.shape
    k = v.shape[1]
    assert n % BLOCK_N == 0, f"n={n} must be a multiple of {BLOCK_N}"
    grid = (n // BLOCK_N,)
    return pl.pallas_call(
        _wy_left_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, k), lambda i: (0, 0)),        # V: replicated
            pl.BlockSpec((k, k), lambda i: (0, 0)),        # T: replicated
            pl.BlockSpec((m, BLOCK_N), lambda i: (0, i)),  # C tile
        ],
        out_specs=pl.BlockSpec((m, BLOCK_N), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, n), c.dtype),
        interpret=True,
    )(v, t, c)


@functools.partial(jax.jit, static_argnames=())
def wy_apply_right(c, v, t):
    """``C ← C·Q`` for ``Q = I − V T Vᵀ``; C is (mrows, m) with mrows a
    multiple of BLOCK_M."""
    mrows, m = c.shape
    k = v.shape[1]
    assert mrows % BLOCK_M == 0, f"mrows={mrows} must be a multiple of {BLOCK_M}"
    grid = (mrows // BLOCK_M,)
    return pl.pallas_call(
        _wy_right_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, k), lambda i: (0, 0)),
            pl.BlockSpec((k, k), lambda i: (0, 0)),
            pl.BlockSpec((BLOCK_M, m), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_M, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mrows, m), c.dtype),
        interpret=True,
    )(v, t, c)
